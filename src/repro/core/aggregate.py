"""Persistent aggregate state for incremental refinement (DESIGN.md §10).

The paper's point is that a turn's decision needs only *aggregate* state:
the (N, K) adjacency aggregate A[i, k] = sum_j c_ij 1[r_j = k], the O(K)
load vector, and the global potentials.  The recompute path rebuilds A
from scratch every turn — an (N,N) @ (N,K) matmul, O(N^2 K) — and pays two
more O(N^2) passes per turn for the traced potentials.  This module keeps
all of it in the ``lax.while_loop`` / ``lax.scan`` carry instead:

  * a move of node l from machine s to d is a **rank-1 column update**
        A[:, s] -= c[:, l]        A[:, d] += c[:, l]
    (column l of the symmetric adjacency), O(N);
  * the loads update is the O(1) two-entry delta the paper's protocol
    already exchanges;
  * both global potentials update via the **exact-potential identities**
    (Thm. 3.1:  ΔC_0 = 2 ΔC_l;  Thm. 5.1:  ΔCt_0 = ΔCt_l), where ΔC_l /
    ΔCt_l are read off the moved node's O(K) cost rows — no O(N^2) pass.

Invariants carried by :class:`AggregateState` (asserted by
``tests/test_incremental.py`` and the ``verify_every`` cross-check),
stated over either graph representation — for a dense problem
``c[i, l]`` is an adjacency entry, for a sparse one
(:class:`~repro.core.sparse.SparseProblem`, DESIGN.md §13) it is the
weight of edge (i, l) in the edge list (0 when absent):

  I1.  aggregate[i, k] == sum over incident edges (i, j) of
       w_ij * 1[r_j = k]  — dense: ``adjacency @ one_hot(assignment)``;
       sparse: ``segment_sum`` of edge one-hots over sender slabs
       (up to f32 drift either way)
  I2.  loads[k]  == sum_{i: r_i = k} b_i
  I3.  c0  == C_0(assignment)   and   ct0 == Ct_0(assignment)
  I4.  cut(assignment) == 0.5 * (sum_i degree_i - sum_i A[i, r_i]) — the
       O(N) identity the §4.5 sweep mode uses to re-derive the cut after a
       rank-K update (simultaneous moves are not unilateral, so the
       exact-potential identities do not apply; instead both potentials
       are O(K) closed forms of (loads, sq_loads, cut), see
       :func:`repro.core.costs.potentials_closed_form`).

The carried (N, K) aggregate is the same object for both — only how
moves update it differs: a dense move applies column l of the adjacency
(O(N)); a sparse move scatters the moved node's ``max_degree`` incident
edge window (O(deg), :func:`repro.core.sparse.node_incident_edges`).

Drift: every quantity is updated by exact +/- of input values, so f32
error grows only with the number of moves that touch an entry.  The
``verify_every=M`` option of the refinement engines rebuilds the state
from scratch every M turns, records the observed drift, and resyncs —
bounding the error for arbitrarily long runs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import costs
from .problem import PartitionProblem, machine_loads
from .sparse import SparseProblem, node_incident_edges

Array = jax.Array

AnyProblem = costs.AnyProblem


class AggregateState(NamedTuple):
    """Everything a refinement turn needs, carried through the loop."""
    assignment: Array   # (N,) int32
    loads: Array        # (K,) float — L_k = sum of owned b
    aggregate: Array    # (N, K) float — A[i, k] = sum_j c_ij 1[r_j = k]
    c0: Array           # ()  float — C_0(assignment)   (Thm. 3.1 potential)
    ct0: Array          # ()  float — Ct_0(assignment)  (Eq. 8 potential)


def init_aggregate_state(problem: AnyProblem,
                         assignment: Array) -> AggregateState:
    """Build the carry from scratch: one O(N^2 K) aggregate matmul and one
    O(N^2) pass per potential — paid once, then never again.  Sparse
    problems pay O(E K) + O(E) instead (segment sums over the edge list,
    closed-form potentials — DESIGN.md §13.2)."""
    assignment = jnp.asarray(assignment, jnp.int32)
    k = problem.num_machines
    aggregate = costs.problem_aggregate(problem, assignment, k)
    loads = machine_loads(problem.node_weights, assignment, k)
    c0 = costs.global_cost_c0(problem, assignment)
    ct0 = costs.global_cost_ct0(problem, assignment)
    return AggregateState(assignment=assignment, loads=loads,
                          aggregate=aggregate, c0=c0, ct0=ct0)


def node_cost_rows(agg_row: Array, b_node: Array, source: Array,
                   loads: Array, speeds: Array, mu: Array,
                   total_weight: Array) -> tuple[Array, Array]:
    """Both frameworks' O(K) cost rows of one node from its aggregate row.

    ``agg_row`` is A[l, :] (pre-move), ``source`` the node's current
    machine.  Delegates to :func:`costs.cost_matrix_from_aggregate` with a
    single-row block so the numbers are bitwise identical to the full
    cost-matrix rows either path would compute.
    """
    row = agg_row[None, :]
    r_row = source[None]
    b_row = b_node[None]
    c_row = costs.cost_matrix_from_aggregate(
        row, r_row, b_row, loads, speeds, mu, costs.C_FRAMEWORK,
        total_weight=total_weight)[0]
    ct_row = costs.cost_matrix_from_aggregate(
        row, r_row, b_row, loads, speeds, mu, costs.CT_FRAMEWORK,
        total_weight=total_weight)[0]
    return c_row, ct_row


def potential_deltas(agg_row: Array, b_node: Array, source: Array,
                     dest: Array, loads: Array, speeds: Array, mu: Array,
                     total_weight: Array) -> tuple[Array, Array]:
    """(ΔC_0, ΔCt_0) of moving one node from ``source`` to ``dest`` via the
    exact-potential identities — O(K), no global pass.

    Thm. 3.1:  ΔC_0  = 2 (C_l(dest)  - C_l(source))
    Thm. 5.1:  ΔCt_0 =    Ct_l(dest) - Ct_l(source)
    """
    c_row, ct_row = node_cost_rows(agg_row, b_node, source, loads, speeds,
                                   mu, total_weight)
    dc0 = 2.0 * (c_row[dest] - c_row[source])
    dct0 = ct_row[dest] - ct_row[source]
    return dc0, dct0


def apply_move(problem: AnyProblem, agg: AggregateState, node: Array,
               source: Array, dest: Array, do_move: Array,
               total_weight: Array) -> AggregateState:
    """Apply one (gated) unilateral move: rank-1 aggregate update,
    O(1) load delta, O(K) potential deltas via the exact identities.

    Dense path — the rank-1 update is expressed as a dense outer product
    against the ``±1`` one-hot column delta rather than a two-column
    scatter: the values are bitwise identical (the untouched columns add
    an exact ``+0.0``, and an accepted move always has ``source != dest``
    — an own-column argmin yields non-positive net dissatisfaction, and
    rejected turns are discarded by the ``do_move`` select), while the
    dense form vectorizes under ``jax.vmap`` where a batched two-column
    scatter serializes (DESIGN.md §12.2).

    Sparse path (DESIGN.md §13.2) — only the moved node's ``max_degree``
    incident-edge window is scattered into the two affected columns:
    O(deg) work and the O(N^2) adjacency never exists.  Masked window
    slots carry weight 0 and add an exact ``±0.0``.
    """
    b_node = problem.node_weights[node]
    dc0, dct0 = potential_deltas(agg.aggregate[node], b_node, source, dest,
                                 agg.loads, problem.speeds, problem.mu,
                                 total_weight)
    kidx = jnp.arange(agg.loads.shape[0])
    dt = agg.aggregate.dtype
    col_delta = (kidx == dest).astype(dt) - (kidx == source).astype(dt)
    if isinstance(problem, SparseProblem):
        nbrs, w = node_incident_edges(problem, node)
        new_aggregate = agg.aggregate.at[nbrs].add(
            w[:, None] * col_delta[None, :])
    else:
        col = problem.adjacency[node]       # symmetric: row l == column l
        new_aggregate = agg.aggregate + col[:, None] * col_delta[None, :]
    new_assignment = agg.assignment.at[node].set(dest)
    new_loads = agg.loads.at[source].add(-b_node).at[dest].add(b_node)
    return AggregateState(
        assignment=jnp.where(do_move, new_assignment, agg.assignment),
        loads=jnp.where(do_move, new_loads, agg.loads),
        aggregate=jnp.where(do_move, new_aggregate, agg.aggregate),
        c0=jnp.where(do_move, agg.c0 + dc0, agg.c0),
        ct0=jnp.where(do_move, agg.ct0 + dct0, agg.ct0),
    )


# ---------------------------------------------------------------------------
# §4.5 simultaneous sweeps: rank-K update + O(K) closed-form potentials
# ---------------------------------------------------------------------------

def cut_from_aggregate(aggregate: Array, assignment: Array) -> Array:
    """Invariant I4: unordered cut = 0.5 (sum_i degree_i - sum_i A[i, r_i]).

    O(N K) (the row sums) given the carried aggregate — re-derived fresh
    each sweep rather than accumulated, so it never drifts beyond the
    aggregate's own drift.
    """
    degree = jnp.sum(aggregate, axis=-1)
    internal = jnp.take_along_axis(aggregate, assignment[:, None],
                                   axis=1)[:, 0]
    return 0.5 * (jnp.sum(degree) - jnp.sum(internal))


# canonical home moved to costs.py so the sparse global potentials can
# share it without an import cycle; re-exported here for the §10 API
potentials_closed_form = costs.potentials_closed_form


def apply_sweep(problem: AnyProblem, agg: AggregateState, picks: Array,
                dests: Array, will_move: Array,
                total_weight: Array) -> AggregateState:
    """Apply a §4.5 sweep: machine m moves node picks[m] (owned by m) to
    dests[m] wherever will_move[m] — a rank-K aggregate update, then both
    potentials via (loads, sq_loads, cut) closed forms.

    ``picks`` entries of idle machines may be garbage (argmax fallback);
    their columns are zeroed by the mask so they contribute exactly 0.
    Sparse problems scatter the K moved nodes' incident-edge windows
    (O(K·max_degree)) instead of the K dense adjacency columns.
    """
    k = problem.num_machines
    b = problem.node_weights
    mask = will_move.astype(agg.aggregate.dtype)              # (K,)
    # sources are exactly 0..K-1 (machine m moves an m-owned node)
    if isinstance(problem, SparseProblem):
        nbrs, ws = jax.vmap(lambda nd: node_incident_edges(problem, nd)
                            )(picks)                          # (K, Dmax)
        ws = ws * mask[:, None]
        kidx = jnp.arange(k)
        col_delta = (dests[:, None] == kidx[None, :]).astype(ws.dtype) \
            - (kidx[None, :] == kidx[:, None]).astype(ws.dtype)   # (K, K)
        new_aggregate = agg.aggregate.at[nbrs].add(
            ws[:, :, None] * col_delta[:, None, :])           # dups summed
    else:
        cols = problem.adjacency[:, picks] * mask[None, :]    # (N, K)
        new_aggregate = agg.aggregate - cols
        new_aggregate = new_aggregate.at[:, dests].add(cols)  # dups summed
    safe_picks = jnp.where(will_move, picks, jnp.int32(problem.num_nodes))
    new_assignment = agg.assignment.at[safe_picks].set(dests, mode="drop")
    new_loads = machine_loads(b, new_assignment, k)
    sq_loads = machine_loads(b * b, new_assignment, k)
    cut = cut_from_aggregate(new_aggregate, new_assignment)
    c0, ct0 = potentials_closed_form(new_loads, sq_loads, cut,
                                     problem.speeds, problem.mu,
                                     total_weight)
    return AggregateState(assignment=new_assignment, loads=new_loads,
                          aggregate=new_aggregate, c0=c0, ct0=ct0)


def apply_moves(problem: AnyProblem, agg: AggregateState, nodes: Array,
                dests: Array, will_move: Array,
                total_weight: Array) -> AggregateState:
    """Apply up to R simultaneous moves (DESIGN.md §17): node ``nodes[r]``
    migrates to ``dests[r]`` wherever ``will_move[r]`` — a rank-R
    aggregate update, then both potentials via the (loads, sq_loads,
    cut) closed forms, exactly like :func:`apply_sweep`.

    The generalization over :func:`apply_sweep` is that sources are read
    from the carried assignment instead of being the machine ids 0..K-1,
    so R is free: the multi-move sweep mode elects up to
    ``moves_per_machine`` nodes per machine (R = K·M, via ``top_k`` over
    disjoint ownership rows, so real picks never collide).  Masked slots
    (``will_move[r]`` False — idle elections, coin rejections) have
    their edge/column contributions zeroed and their assignment writes
    dropped, contributing an exact ``±0.0``.

    Sparse problems scatter the R moved nodes' incident-edge windows
    (O(R·max_degree·K)); dense ones apply one (N, R) @ (R, K) matmul of
    gathered adjacency columns against the ``±1`` one-hot column deltas.
    """
    k = problem.num_machines
    b = problem.node_weights
    dt = agg.aggregate.dtype
    mask = will_move.astype(dt)                               # (R,)
    sources = agg.assignment[nodes]                           # (R,)
    kidx = jnp.arange(k)
    col_delta = (dests[:, None] == kidx[None, :]).astype(dt) \
        - (sources[:, None] == kidx[None, :]).astype(dt)      # (R, K)
    if isinstance(problem, SparseProblem):
        nbrs, ws = jax.vmap(lambda nd: node_incident_edges(problem, nd)
                            )(nodes)                          # (R, Dmax)
        ws = ws * mask[:, None]
        new_aggregate = agg.aggregate.at[nbrs].add(
            ws[:, :, None] * col_delta[:, None, :])           # dups summed
    else:
        cols = problem.adjacency[:, nodes] * mask[None, :]    # (N, R)
        new_aggregate = agg.aggregate + cols @ col_delta
    safe_nodes = jnp.where(will_move, nodes, jnp.int32(problem.num_nodes))
    new_assignment = agg.assignment.at[safe_nodes].set(dests, mode="drop")
    new_loads = machine_loads(b, new_assignment, k)
    sq_loads = machine_loads(b * b, new_assignment, k)
    cut = cut_from_aggregate(new_aggregate, new_assignment)
    c0, ct0 = potentials_closed_form(new_loads, sq_loads, cut,
                                     problem.speeds, problem.mu,
                                     total_weight)
    return AggregateState(assignment=new_assignment, loads=new_loads,
                          aggregate=new_aggregate, c0=c0, ct0=ct0)


def apply_cluster_move(problem: AnyProblem, agg: AggregateState, mask: Array,
                       source: Array, dest: Array, do_move: Array,
                       total_weight: Array) -> AggregateState:
    """Apply a §7 cluster move: every node in the boolean ``mask`` (all
    owned by ``source``) migrates jointly to ``dest`` when ``do_move``.

    The aggregate update is a two-column group update: for every node i,
    ``delta_i = sum_{j in cluster} c_ij`` moves from column ``source``
    to column ``dest`` — one O(E) masked ``segment_sum`` on sparse
    problems (the cluster members' combined incident weight per node),
    one O(N^2) masked matvec on dense ones.  Potentials are re-derived
    via the closed forms (a cluster move is not unilateral, so the
    exact-potential identities do not apply — same reasoning as
    :func:`apply_sweep`).
    """
    k = problem.num_machines
    b = problem.node_weights
    dt = agg.aggregate.dtype
    if isinstance(problem, SparseProblem):
        hit = jnp.where(mask[problem.receivers], problem.edge_weights,
                        jnp.zeros((), dt))
        delta = jax.ops.segment_sum(hit, problem.senders,
                                    num_segments=problem.num_nodes,
                                    indices_are_sorted=True)  # (N,)
    else:
        delta = problem.adjacency @ mask.astype(dt)           # (N,)
    kidx = jnp.arange(k)
    col_delta = (kidx == dest).astype(dt) - (kidx == source).astype(dt)
    new_aggregate = agg.aggregate + delta[:, None] * col_delta[None, :]
    new_assignment = jnp.where(mask, dest, agg.assignment).astype(jnp.int32)
    new_loads = machine_loads(b, new_assignment, k)
    sq_loads = machine_loads(b * b, new_assignment, k)
    cut = cut_from_aggregate(new_aggregate, new_assignment)
    c0, ct0 = potentials_closed_form(new_loads, sq_loads, cut,
                                     problem.speeds, problem.mu,
                                     total_weight)
    new = AggregateState(assignment=new_assignment, loads=new_loads,
                         aggregate=new_aggregate, c0=c0, ct0=ct0)
    return jax.tree.map(lambda n_, o: jnp.where(do_move, n_, o), new, agg)


def rebuild_state(problem: AnyProblem, assignment: Array,
                  total_weight: Array) -> AggregateState:
    """Build a fresh :class:`AggregateState` with closed-form potentials.

    Same carried quantities as :func:`init_aggregate_state`, but C_0 and
    Ct_0 come from :func:`repro.core.costs.potentials_closed_form` over
    (loads, sq_loads, cut) — O(E·K) + O(K) total — instead of the
    representation-dispatched global passes.  This is the overflow path
    of the unbounded multi-move mode (DESIGN.md §17): when a sweep's
    accepted set outgrows the mover buffer the rank-R scatter would be
    O(N)-wide, and a from-scratch rebuild is both cheaper and drift-free
    by construction.
    """
    assignment = jnp.asarray(assignment, jnp.int32)
    k = problem.num_machines
    b = problem.node_weights
    aggregate = costs.problem_aggregate(problem, assignment, k)
    loads = machine_loads(b, assignment, k)
    sq_loads = machine_loads(b * b, assignment, k)
    cut = cut_from_aggregate(aggregate, assignment)
    c0, ct0 = potentials_closed_form(loads, sq_loads, cut, problem.speeds,
                                     problem.mu, total_weight)
    return AggregateState(assignment=assignment, loads=loads,
                          aggregate=aggregate, c0=c0, ct0=ct0)


# ---------------------------------------------------------------------------
# verify_every cross-check
# ---------------------------------------------------------------------------

def resync(problem: AnyProblem, agg: AggregateState
           ) -> tuple[AggregateState, Array]:
    """Rebuild the carry from scratch, returning (fresh state, observed
    drift) — drift being the max absolute deviation of any carried
    quantity from its from-scratch value (the ``verify_every`` bound)."""
    fresh = init_aggregate_state(problem, agg.assignment)
    observed = jnp.maximum(
        jnp.max(jnp.abs(agg.aggregate - fresh.aggregate)),
        jnp.maximum(
            jnp.max(jnp.abs(agg.loads - fresh.loads)),
            jnp.maximum(jnp.abs(agg.c0 - fresh.c0),
                        jnp.abs(agg.ct0 - fresh.ct0))))
    return fresh, observed


def drift(problem: AnyProblem, agg: AggregateState) -> Array:
    """Max absolute deviation of the carried state from a rebuild."""
    return resync(problem, agg)[1]


def repair_columns(problem: AnyProblem, agg: AggregateState, tol: float
                   ) -> tuple[AggregateState, Array, Array]:
    """Active repair (DESIGN.md §15.3): rebuild from scratch like
    :func:`resync`, but patch ONLY the quantities that actually deviate
    beyond ``tol`` — per machine-column for the (N, K) aggregate, per
    entry for the loads, and per scalar (relative) for the potentials.
    Clean state passes through bitwise untouched, so a repair boundary
    on an undrifted carry is a no-op rather than a wholesale rewrite.

    Detection predicates are NaN-safe (``~(dev <= tol)`` flags NaN and
    inf as corrupt), so bit-corrupted columns are always caught.

    Returns ``(repaired, observed, cols)``: the patched state, the max
    pre-repair deviation (NaN mapped to inf — same convention as the
    ``verify_every`` drift record), and the number of aggregate columns
    patched.
    """
    fresh = init_aggregate_state(problem, agg.assignment)
    inf_dev = lambda x: jnp.nan_to_num(x, nan=jnp.inf, posinf=jnp.inf)

    col_dev = jnp.max(jnp.abs(agg.aggregate - fresh.aggregate), axis=0)  # (K,)
    col_bad = ~(col_dev <= tol)
    aggregate = jnp.where(col_bad[None, :], fresh.aggregate, agg.aggregate)

    load_dev = jnp.abs(agg.loads - fresh.loads)
    load_bad = ~(load_dev <= tol)
    loads = jnp.where(load_bad, fresh.loads, agg.loads)

    # Potentials are O(N^2)-sized f32 sums — compare relatively.
    def patch_scalar(live, ref):
        dev = jnp.abs(live - ref)
        bad = ~(dev <= tol * jnp.maximum(1.0, jnp.abs(ref)))
        return jnp.where(bad, ref, live), inf_dev(dev)

    c0, c0_dev = patch_scalar(agg.c0, fresh.c0)
    ct0, ct0_dev = patch_scalar(agg.ct0, fresh.ct0)

    observed = jnp.maximum(
        jnp.max(inf_dev(col_dev)),
        jnp.maximum(jnp.max(inf_dev(load_dev)),
                    jnp.maximum(c0_dev, ct0_dev)))
    repaired = AggregateState(assignment=agg.assignment, loads=loads,
                              aggregate=aggregate, c0=c0, ct0=ct0)
    return repaired, observed, jnp.sum(col_bad.astype(jnp.int32))
