"""Sparse edge-list problem representation (DESIGN.md §13).

The dense ``PartitionProblem`` carries an (N, N) adjacency — an O(N^2)
memory/compute floor that caps the benchmarks at N=4096 even though the
paper's §5.1 topologies have 3–6 edges per node.  ``SparseProblem`` is
the first-class sparse sibling: a **padded, sender-sorted COO/CSR edge
list** (every undirected edge stored in both directions) that the whole
refinement stack — costs, aggregates, the three refinement entry points,
the Pallas edge-block kernel and the batched sweep runtime — consumes
directly, so an N=10^5–10^6 topology never materializes an O(N^2) array.

Layout (DESIGN.md §13.1):

  * ``senders`` / ``receivers`` / ``edge_weights`` — (E,) arrays of the
    DIRECTED edge list: each undirected edge {i, j} appears as (i, j)
    and (j, i) with the same weight, rows sorted by sender (receivers
    ascending within a sender), so node i's incident edges occupy the
    contiguous slab ``[row_start[i], row_start[i] + degree_i)``.
  * **Padding** — E is rounded up (default: multiple of 128) with slots
    ``sender = N-1, receiver = 0, weight = 0.0``: sortedness is kept,
    every index stays in-bounds, and a zero-weight edge contributes an
    exact ``+0.0`` to every sum it touches, so padded and unpadded
    problems produce identical numbers.
  * ``row_start`` — (N,) first edge index per node (CSR offsets).
  * ``max_degree`` — static upper bound on any node's degree (rounded
    up, default multiple of 8).  A move touches only the moved node's
    incident edges, fetched as one ``max_degree``-sized dynamic slice —
    O(deg) instead of the dense path's O(N) adjacency row.

Everything downstream keys off ``isinstance(problem, SparseProblem)``
at trace time: aggregates become ``segment_sum`` over edges, the cut
and both global potentials become O(E)/O(K) edge/closed-form sums, and
``repro.core.aggregate.apply_move`` scatters into the carried (N, K)
aggregate along the incident-edge slab.  The (N, K) aggregate itself is
kept dense — it is the paper's own O(NK) machine-facing state, not part
of the O(N^2) problem.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from .problem import (PartitionProblem, ProblemValidationError,
                      _is_concrete, make_problem)

Array = jax.Array

EDGE_PAD_MULTIPLE = 128     # padded E is a multiple of this (lane width)
DEGREE_PAD_MULTIPLE = 8     # static max_degree rounds up to this

# Declared asymptotic budgets for the sparse representation, consumed by
# the complexity analyzers (DESIGN.md §18).  Sparse paths promise
# O(E + N*K) memory and work: at most linear in N (at fixed degree),
# linear in E (the degree sweep), linear in K.  A fitted N-exponent
# near 2 means some equation materialized a dense (N, N)-shaped
# intermediate — exactly the regression the sparse path exists to
# prevent (ROADMAP items 1-2).
SPARSE_COMPLEXITY = {
    "mem": {"n": 1.0, "e": 1.0, "k": 1.0},
    "ops": {"n": 1.0, "e": 1.0, "k": 1.0},
}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseProblem:
    """Sparse partition-game problem: padded sender-sorted edge list.

    Same game as :class:`~repro.core.problem.PartitionProblem` (node
    weights ``b_i``, machine speeds ``w_k``, cut weight ``mu``), with the
    graph as edges instead of an (N, N) matrix.  ``max_degree`` is
    static metadata (part of the jit trace signature — problems sharing
    it stack and vmap together, see :mod:`repro.sweeps`).
    """
    senders: Array        # (E,) int32, sorted ascending; padding = N-1
    receivers: Array      # (E,) int32; padding = 0
    edge_weights: Array   # (E,) float; padding = 0.0
    row_start: Array      # (N,) int32 CSR offsets into the edge arrays
    node_weights: Array   # (N,) float
    speeds: Array         # (K,) float, sums to 1
    mu: Array             # scalar float
    max_degree: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_nodes(self) -> int:
        return self.node_weights.shape[0]

    @property
    def num_machines(self) -> int:
        return self.speeds.shape[0]

    @property
    def num_edges(self) -> int:
        """PADDED directed edge count (2x undirected + padding)."""
        return self.senders.shape[0]

    def validate(self) -> None:
        """Raise :class:`~repro.core.problem.ProblemValidationError` on
        malformed fields (DESIGN.md §15.7).  Shape/static checks always
        run; value checks (NaN/negative weights, endpoint range,
        ``row_start`` consistency with the sender slabs) only on
        concrete arrays."""
        n, e = self.num_nodes, self.num_edges
        for name, arr in (("senders", self.senders),
                          ("receivers", self.receivers),
                          ("edge_weights", self.edge_weights)):
            if arr.shape != (e,):
                raise ProblemValidationError(
                    f"{name} shape {arr.shape} does not match padded edge "
                    f"count E={e}")
        if self.row_start.shape != (n,):
            raise ProblemValidationError(
                f"row_start shape {self.row_start.shape} does not match "
                f"N={n}")
        if self.speeds.ndim != 1:
            raise ProblemValidationError(
                f"speeds must be (K,); got shape {self.speeds.shape}")
        if self.max_degree < 1:
            raise ProblemValidationError(
                f"max_degree must be >= 1; got {self.max_degree}")
        if e < self.max_degree:
            raise ProblemValidationError(
                f"padded edge count E={e} is smaller than "
                f"max_degree={self.max_degree} (the incident-edge window "
                "would run off the arrays)")
        if not _is_concrete(self.senders, self.receivers,
                            self.edge_weights, self.row_start,
                            self.node_weights, self.speeds):
            return
        s = np.asarray(self.senders)
        r = np.asarray(self.receivers)
        w = np.asarray(self.edge_weights)
        if np.isnan(w).any():
            raise ProblemValidationError("edge_weights contains NaN")
        if (w < 0).any():
            raise ProblemValidationError("edge_weights contains negative "
                                         "weights")
        if s.size and (s.min() < 0 or r.min() < 0
                       or max(s.max(), r.max()) >= n):
            raise ProblemValidationError(
                f"edge endpoints out of range [0, {n})")
        if np.any(np.diff(s) < 0):
            raise ProblemValidationError("senders must be sorted ascending "
                                         "(CSR slab layout)")
        # row_start[i] must open node i's slab: every real (nonzero-
        # weight) edge of sender i must land in
        # [row_start[i], row_start[i] + max_degree).
        rs = np.asarray(self.row_start)
        if np.any(np.diff(rs) < 0) or (rs.size and rs[0] != 0):
            raise ProblemValidationError(
                "row_start must be non-decreasing CSR offsets starting "
                "at 0")
        if rs.size and rs.max() > e:
            raise ProblemValidationError(
                f"row_start points past the edge arrays "
                f"(max {rs.max()} > E={e})")
        real = w != 0
        if real.any():
            idx = np.nonzero(real)[0]
            lo = rs[s[idx]]
            if (idx < lo).any() or (idx >= lo + self.max_degree).any():
                raise ProblemValidationError(
                    "row_start inconsistent with sender slabs: a real "
                    "edge lies outside its sender's "
                    "[row_start, row_start + max_degree) window")
        b = np.asarray(self.node_weights)
        if np.isnan(b).any() or (b < 0).any():
            raise ProblemValidationError("node_weights must be finite and "
                                         "non-negative")
        sp = np.asarray(self.speeds)
        if np.isnan(sp).any() or (sp <= 0).any():
            raise ProblemValidationError("speeds must be finite and "
                                         "positive")


def _round_up(x: int, multiple: int) -> int:
    return -(-max(x, 1) // multiple) * multiple


def make_sparse_problem(senders, receivers, edge_weights, node_weights,
                        speeds, mu: float = 8.0, *,
                        normalize_speeds: bool = True, dtype=jnp.float32,
                        pad_edges_multiple: int = EDGE_PAD_MULTIPLE,
                        pad_degree_multiple: int = DEGREE_PAD_MULTIPLE,
                        ) -> SparseProblem:
    """Build a :class:`SparseProblem` from an UNDIRECTED edge list.

    ``senders``/``receivers``/``edge_weights`` list each undirected edge
    once (either orientation); self-loops are dropped and duplicate
    {i, j} entries have their weights summed (host-side numpy — graphs
    are data, mirroring :mod:`repro.graphs.generators`).  Both directed
    orientations are emitted, sorted by (sender, receiver), padded per
    the DESIGN.md §13.1 rules above.
    """
    s = np.asarray(senders, np.int64).ravel()
    r = np.asarray(receivers, np.int64).ravel()
    w = np.asarray(edge_weights, np.float64).ravel()
    if not (s.shape == r.shape == w.shape):
        raise ProblemValidationError(
            f"edge arrays disagree: {s.shape}, {r.shape}, {w.shape}")
    node_weights = np.asarray(node_weights, np.float64).ravel()
    n = node_weights.shape[0]
    if s.size and (s.min() < 0 or r.min() < 0 or max(s.max(), r.max()) >= n):
        raise ProblemValidationError("edge endpoints out of range")

    keep = s != r                                    # no self loops
    a = np.minimum(s[keep], r[keep])
    b = np.maximum(s[keep], r[keep])
    w = w[keep]
    # canonicalize + sum duplicate undirected edges
    code = a * n + b
    order = np.argsort(code, kind="stable")
    code, w = code[order], w[order]
    uniq, first = np.unique(code, return_index=True)
    w = np.add.reduceat(w, first) if w.size else w
    a, b = uniq // n, uniq % n

    # both directions, sorted by (sender, receiver)
    ds = np.concatenate([a, b])
    dr = np.concatenate([b, a])
    dw = np.concatenate([w, w])
    order = np.lexsort((dr, ds))
    ds, dr, dw = ds[order], dr[order], dw[order]

    degree = np.bincount(ds, minlength=n)
    max_degree = _round_up(int(degree.max(initial=1)), pad_degree_multiple)
    e_pad = _round_up(max(ds.size, max_degree), pad_edges_multiple)
    row_start = np.zeros(n, np.int64)
    row_start[1:] = np.cumsum(degree)[:-1]

    pad = e_pad - ds.size
    ds = np.concatenate([ds, np.full(pad, n - 1)])
    dr = np.concatenate([dr, np.zeros(pad, np.int64)])
    dw = np.concatenate([dw, np.zeros(pad)])

    speeds = jnp.asarray(np.asarray(speeds, np.float64), dtype)
    if normalize_speeds:
        speeds = speeds / jnp.sum(speeds)
    prob = SparseProblem(
        senders=jnp.asarray(ds, jnp.int32),
        receivers=jnp.asarray(dr, jnp.int32),
        edge_weights=jnp.asarray(dw, dtype),
        row_start=jnp.asarray(row_start, jnp.int32),
        node_weights=jnp.asarray(node_weights, dtype),
        speeds=speeds,
        mu=jnp.asarray(mu, dtype),
        max_degree=max_degree,
    )
    prob.validate()
    return prob


def sparse_from_dense(problem: PartitionProblem, **kwargs) -> SparseProblem:
    """Convert a dense problem to its sparse edge-list twin.

    The dense adjacency is already symmetric with zero diagonal
    (``make_problem`` enforces it), so the upper triangle enumerates
    each undirected edge exactly once with its final weight.
    """
    adj = np.asarray(problem.adjacency)
    iu, ju = np.nonzero(np.triu(adj, k=1))
    return make_sparse_problem(
        iu, ju, adj[iu, ju], np.asarray(problem.node_weights),
        np.asarray(problem.speeds), np.asarray(problem.mu),
        normalize_speeds=False, dtype=problem.adjacency.dtype, **kwargs)


def dense_from_sparse(sp: SparseProblem) -> PartitionProblem:
    """Materialize the (N, N) adjacency — small-N tests/oracles only."""
    n = sp.num_nodes
    adj = np.zeros((n, n), np.asarray(sp.edge_weights).dtype)
    s = np.asarray(sp.senders)
    r = np.asarray(sp.receivers)
    w = np.asarray(sp.edge_weights)
    np.add.at(adj, (s, r), w)          # padding adds 0.0 at (N-1, 0)
    return make_problem(adj, np.asarray(sp.node_weights),
                        np.asarray(sp.speeds), np.asarray(sp.mu),
                        normalize_speeds=False)


def frontier_expand(sp: SparseProblem, mask: Array) -> Array:
    """One BFS frontier step over the edge list: ``mask`` grown by every
    node adjacent (through a real, nonzero-weight edge) to a masked node
    — the O(E) CSR replacement for the dense ``mask @ (adj > 0)`` step
    of :func:`repro.core.cluster.h_hop_mask` (DESIGN.md §17.3).

    Each undirected edge is stored in both directions, so testing the
    RECEIVER endpoint and ``segment_max``-reducing over the sender slabs
    reaches every neighbor; padded edges carry weight 0 and can never
    fire.
    """
    hit = mask[sp.receivers] & (sp.edge_weights > 0)
    reached = jax.ops.segment_max(hit.astype(jnp.int32), sp.senders,
                                  num_segments=sp.num_nodes,
                                  indices_are_sorted=True)
    return mask | (reached > 0)


def node_incident_edges(sp: SparseProblem, node: Array
                        ) -> tuple[Array, Array]:
    """(neighbors, weights) of one node as a ``max_degree`` window — the
    O(deg) replacement for the dense path's O(N) adjacency row.

    One dynamic slice at ``row_start[node]``; slots whose sender is not
    ``node`` (tail padding, or spill-over when the slice clamps at the
    array end) are masked to weight 0, which contributes an exact
    ``+0.0`` wherever the window is scattered (DESIGN.md §13.2).
    """
    start = sp.row_start[node]
    d = sp.max_degree
    s = jax.lax.dynamic_slice_in_dim(sp.senders, start, d)
    r = jax.lax.dynamic_slice_in_dim(sp.receivers, start, d)
    w = jax.lax.dynamic_slice_in_dim(sp.edge_weights, start, d)
    return r, jnp.where(s == node, w, jnp.zeros((), w.dtype))
