"""Run telemetry layer (DESIGN.md §14).

Structured, typed event streams from every execution axis of the repo —
the three ``repro.core.refine`` entry points, the DES engine ticks, the
four ``repro.distributed`` drivers (with *measured* wire-byte counters
reconciled against the analytic ledger), and the batched sweep runtime —
plus sinks (JSONL run logs, Chrome-trace/Perfetto phase timing) and a
replay/report CLI (``python -m repro.obs.report``).

Telemetry is strictly opt-in: every instrumented entry point takes
``recorder=None`` and the ``None`` path is the exact pre-telemetry
computation — same jaxpr, no host callbacks, bitwise-identical results
(``tests/test_obs.py`` pins both properties).
"""
from .events import EVENT_KINDS, make_event, validate_event
from .recorder import Recorder
from .sinks import JsonlSink, MemorySink, chrome_trace, read_jsonl

__all__ = [
    "EVENT_KINDS",
    "JsonlSink",
    "MemorySink",
    "Recorder",
    "chrome_trace",
    "make_event",
    "read_jsonl",
    "validate_event",
]
