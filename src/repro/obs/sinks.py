"""Telemetry sinks: JSONL run logs and Chrome-trace export (DESIGN.md §14.2).

A sink is any object with ``write(event: dict)`` and optional
``flush()`` / ``close()``; the :class:`~repro.obs.recorder.Recorder`
fans every emitted event out to all attached sinks.  Two concrete sinks
ship here — :class:`MemorySink` (in-process list, used by tests and the
replay helpers) and :class:`JsonlSink` (one JSON object per line, the
on-disk run-log format the report CLI consumes) — plus
:func:`chrome_trace`, which converts the ``phase`` events of a run log
into the Chrome ``traceEvents`` JSON that chrome://tracing and Perfetto
load directly.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import IO, Iterable

from .events import validate_event


class MemorySink:
    """Collects events in a list (``sink.events``)."""

    def __init__(self):
        self.events: list[dict] = []

    def write(self, event: dict) -> None:
        self.events.append(event)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlSink:
    """Appends one compact JSON object per event to ``path``."""

    def __init__(self, path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh: IO[str] = open(self.path, "a", encoding="utf-8")

    def write(self, event: dict) -> None:
        self._fh.write(json.dumps(event, separators=(",", ":")) + "\n")

    def flush(self) -> None:
        self._fh.flush()

    def close(self) -> None:
        self._fh.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path, validate: bool = True) -> list[dict]:
    """Load a JSONL run log back into event dicts (blank lines skipped)."""
    events = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            event = json.loads(line)
            if validate:
                validate_event(event)
            events.append(event)
    return events


def chrome_trace(events: Iterable[dict]) -> dict:
    """Convert ``phase`` events to Chrome trace format (``traceEvents``).

    Each phase becomes one complete (``ph: "X"``) slice; runs map to
    trace *threads* so concurrent runs in one log stay visually
    separated.  Timestamps are microseconds relative to the earliest
    phase in the log, as the trace viewers expect.
    """
    phases = [e for e in events if e.get("kind") == "phase"]
    t0 = min((e["ts"] for e in phases), default=0.0)
    runs = sorted({e["run"] for e in phases})
    tids = {run: i for i, run in enumerate(runs)}
    trace_events = [{
        "name": e["name"],
        "ph": "X",
        "ts": (e["ts"] - t0) * 1e6,
        "dur": max(e["dur"], 0.0) * 1e6,
        "pid": 0,
        "tid": tids[e["run"]],
        "args": {"run": e["run"]},
    } for e in phases]
    trace_events.extend({
        "name": "thread_name",
        "ph": "M",
        "pid": 0,
        "tid": tid,
        "args": {"name": run},
    } for run, tid in tids.items())
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def write_chrome_trace(events: Iterable[dict], path) -> Path:
    """Write :func:`chrome_trace` output to ``path`` (returns the path)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(chrome_trace(events), indent=1))
    return path
