"""Replay + report CLI for telemetry run logs (DESIGN.md §14.4).

``python -m repro.obs.report RUN.jsonl`` renders per-run convergence /
load-CV / wire summaries from a JSONL log alone — no device, no problem
arrays.  The core is :func:`replay_run`: starting from the ``run_start``
machine loads it re-applies every accepted move's ``(source, dest,
weight)``, reconstructing the weighted-load CV descent trace and the
final loads, and collects the carried potential trace from the ``turn``/
``sweep`` events.  :func:`check_run` then cross-checks the replay
against the ``run_end`` ground truth (final loads, move count), verifies
potential descent for sequential runs, and enforces the ``wire`` and
``drift`` verdicts — ``--check`` exits nonzero on any failure, which is
what the CI bench-smoke job gates on.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

import numpy as np

from .sinks import read_jsonl

SEQUENTIAL_RUNTIMES = {"refine", "refine_traced", "distributed",
                       "distributed_traced", "shard_map"}
# f32 potentials are O(1e6) sums; allow this relative slack before calling
# a carried-potential ascent a descent violation.
ASCENT_REL_TOL = 1e-5


def split_runs(events) -> dict[str, list[dict]]:
    """Group a log's events by run id, preserving order."""
    runs: dict[str, list[dict]] = {}
    for event in events:
        runs.setdefault(event["run"], []).append(event)
    return runs


def _cv(loads: np.ndarray, speeds: np.ndarray) -> float:
    weighted = loads / speeds
    mean = weighted.mean()
    return float(weighted.std() / max(mean, 1e-12))


def replay_run(events: list[dict]) -> dict:
    """Reconstruct one run's traces from its event stream alone.

    Returns a summary dict with the replayed ``loads`` / ``load_cv`` /
    ``cv_trace``, the potential trace ``potentials`` (list of ``(t, c0,
    ct0)`` for turns/sweeps that carry them), accept/reject counters,
    and the raw ``wire`` / ``drift`` / ``run_end`` events for checking.
    """
    start = next((e for e in events if e["kind"] == "run_start"), None)
    if start is None:
        raise ValueError("run has no run_start event")
    summary: dict = {
        "run": start["run"],
        "runtime": start["runtime"],
        "meta": {k: v for k, v in start.items()
                 if k not in ("kind", "run", "loads", "speeds")},
    }
    loads = np.asarray(start.get("loads", []), np.float64)
    speeds = np.asarray(start.get("speeds", np.ones_like(loads)), np.float64)
    cv_trace: list[float] = []
    potentials: list[tuple] = []
    accepted = 0
    rejects: dict[str, int] = {}
    movers = 0
    ticks = 0
    des_refines = 0
    frozen_max = 0
    segments: set[int] = set()
    for event in events:
        kind = event["kind"]
        if kind == "turn":
            if event["moved"]:
                accepted += 1
                if loads.size:
                    loads[event["source"]] -= event["weight"]
                    loads[event["dest"]] += event["weight"]
            else:
                reason = event.get("reject") or "unknown"
                rejects[reason] = rejects.get(reason, 0) + 1
            if loads.size:
                cv_trace.append(_cv(loads, speeds))
            if event.get("c0") is not None:
                potentials.append((event["t"], event["c0"], event["ct0"]))
        elif kind == "sweep":
            movers += max(event["movers"], 0)
            potentials.append((event["t"], event["c0"], event["ct0"]))
        elif kind == "tick":
            ticks += 1
            segments.add(event["segment"])
            frozen_max = max(frozen_max, event["frozen"])
        elif kind == "des_refine":
            des_refines += 1
    fault_counts: dict[str, int] = {}
    retries = 0
    undelivered = 0
    max_lag = 0
    quarantined = 0
    for event in events:
        kind = event["kind"]
        if kind == "fault_injected":
            fault_counts[event["fault"]] = \
                fault_counts.get(event["fault"], 0) + 1
        elif kind == "exchange_retry":
            retries += event["attempts"]
            undelivered += 0 if event["delivered"] else 1
        elif kind == "staleness":
            max_lag = max(max_lag, event["lag"])
            quarantined += 1 if event["quarantined"] else 0
    summary.update(
        faults=fault_counts, retries=retries, undelivered=undelivered,
        max_lag=max_lag, quarantined=quarantined,
        repairs=[e for e in events if e["kind"] == "repair"],
        aborted=next((e for e in events if e["kind"] == "run_aborted"),
                     None),
    )
    summary.update(
        accepted=accepted, rejects=rejects, movers=movers,
        loads=loads, load_cv=_cv(loads, speeds) if loads.size else None,
        cv_trace=np.asarray(cv_trace), potentials=potentials,
        ticks=ticks, des_refines=des_refines, frozen_max=frozen_max,
        segments=sorted(segments),
        wire=[e for e in events if e["kind"] == "wire"],
        drift=[e for e in events if e["kind"] == "drift"],
        end=next((e for e in events if e["kind"] == "run_end"), None),
        phases=[e for e in events if e["kind"] == "phase"],
    )
    return summary


def check_run(summary: dict) -> list[str]:
    """Cross-check a replayed run; returns a list of failure strings."""
    problems: list[str] = []
    run = summary["run"]
    end = summary["end"]
    faulty = bool(summary["meta"].get("faults")) or bool(summary["faults"])
    had_turns = summary["accepted"] + sum(summary["rejects"].values()) > 0
    if summary["aborted"] is not None:
        problems.append(f"{run}: run aborted — {summary['aborted']['error']}")
    if faulty:
        # Recover-or-raise verdict (DESIGN.md §15): a fault-injected run
        # must close with an explicit recovered=True.
        if end is None:
            problems.append(f"{run}: fault-injected run has no run_end")
        elif not end.get("recovered", False):
            drift = end.get("recovery_drift")
            problems.append(
                f"{run}: fault-injected run did not recover "
                f"(recovery drift {drift if drift is not None else '?'})")
    if end is not None and summary["loads"].size and had_turns:
        end_loads = np.asarray(end.get("loads", []), np.float64)
        if end_loads.size and not np.allclose(
                summary["loads"], end_loads, rtol=1e-5, atol=1e-3):
            problems.append(
                f"{run}: replayed final loads disagree with run_end "
                f"(max |Δ| = {np.abs(summary['loads'] - end_loads).max():g})")
    if (end is not None and "num_moves" in end
            and summary["runtime"] != "sweep"
            and (summary["accepted"] or summary["movers"])):
        replayed = summary["accepted"] + summary["movers"]
        if replayed != end["num_moves"]:
            problems.append(f"{run}: replayed {replayed} moves, run_end "
                            f"reports {end['num_moves']}")
    # Degraded-mode moves elected on stale aggregates (and repair jumps)
    # may transiently ascend — the recover-or-raise verdict above is the
    # fault-injected run's correctness gate, not strict descent.
    if summary["runtime"] in SEQUENTIAL_RUNTIMES and not faulty:
        pots = summary["potentials"]
        for (t0, c0a, _), (t1, c0b, _) in zip(pots, pots[1:]):
            if c0b - c0a > ASCENT_REL_TOL * abs(c0a) and not math.isnan(c0b):
                problems.append(f"{run}: carried C_0 ascends at turn {t1} "
                                f"({c0a:g} -> {c0b:g})")
                break
    for event in summary["wire"]:
        if not event["ok"]:
            problems.append(
                f"{run}: measured wire bytes disagree with ledger "
                f"(payload {event['measured_payload']} vs "
                f"{event['predicted_payload']}, setup "
                f"{event['measured_setup']} vs {event['predicted_setup']})")
    for event in summary["drift"]:
        if event["value"] > event["budget"]:
            problems.append(f"{run}: aggregate drift {event['value']:g} "
                            f"exceeds budget {event['budget']:g}")
    return problems


def render(summary: dict) -> str:
    """One human-readable block per run."""
    lines = [f"run {summary['run']}  [{summary['runtime']}]"]
    meta = summary["meta"]
    known = {k: meta[k] for k in ("framework", "n", "k", "num_shards")
             if k in meta}
    if known:
        lines.append("  " + "  ".join(f"{k}={v}" for k, v in known.items()))
    if summary["accepted"] or summary["rejects"]:
        rej = ", ".join(f"{k}:{v}" for k, v in sorted(
            summary["rejects"].items())) or "none"
        lines.append(f"  turns: {summary['accepted']} accepted, "
                     f"rejected {{{rej}}}")
    if summary["movers"]:
        lines.append(f"  sweeps: {summary['movers']} total movers")
    pots = summary["potentials"]
    if pots:
        lines.append(f"  potential C_0: {pots[0][1]:.6g} -> {pots[-1][1]:.6g}"
                     f"  (Ct_0 {pots[0][2]:.6g} -> {pots[-1][2]:.6g})")
    if summary["cv_trace"].size:
        lines.append(f"  load CV: {summary['cv_trace'][0]:.4f} -> "
                     f"{summary['cv_trace'][-1]:.4f}")
    if summary["ticks"]:
        lines.append(f"  des: {summary['ticks']} ticks, "
                     f"{summary['des_refines']} refine rounds, "
                     f"max frozen {summary['frozen_max']}, "
                     f"segments {summary['segments']}")
    for event in summary["wire"]:
        verdict = "OK" if event["ok"] else "MISMATCH"
        lines.append(f"  wire [{verdict}]: {event['rounds']} rounds, "
                     f"payload {event['measured_payload']} B measured / "
                     f"{event['predicted_payload']} B predicted, setup "
                     f"{event['measured_setup']} / "
                     f"{event['predicted_setup']} B")
    for event in summary["drift"]:
        lines.append(f"  drift: {event['value']:g} (budget "
                     f"{event['budget']:g})")
    if summary["faults"]:
        injected = ", ".join(f"{k}:{v}" for k, v in
                             sorted(summary["faults"].items()))
        lines.append(f"  faults: {{{injected}}}, {summary['retries']} "
                     f"retry attempts ({summary['undelivered']} given up), "
                     f"max staleness {summary['max_lag']}, "
                     f"{summary['quarantined']} quarantined shard-rounds")
    if summary["repairs"]:
        cols = sum(e["cols"] or 0 for e in summary["repairs"])
        drifts = [e["drift"] for e in summary["repairs"]
                  if e["drift"] is not None]
        worst = f", worst drift {max(drifts):g}" if drifts else ""
        lines.append(f"  repairs: {len(summary['repairs'])} "
                     f"({cols} columns patched{worst})")
    if summary["aborted"] is not None:
        lines.append(f"  ABORTED: {summary['aborted']['error']}")
    end = summary["end"]
    if end is not None:
        extra = f", wall {end['wall']:.3f}s" if "wall" in end else ""
        if "recovered" in end:
            verdict = "recovered" if end["recovered"] else "NOT RECOVERED"
            rd = end.get("recovery_drift")
            extra += f", {verdict}" + \
                (f" (drift {rd:g})" if rd is not None else "")
        lines.append(f"  end: moves={end.get('num_moves')} "
                     f"turns={end.get('num_turns')} "
                     f"converged={end.get('converged')}{extra}")
    if summary["phases"]:
        total = sum(e["dur"] for e in summary["phases"])
        lines.append(f"  phases: {len(summary['phases'])} spans, "
                     f"{total:.3f}s total")
    return "\n".join(lines)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render convergence/CV/wire summaries from a telemetry "
                    "JSONL run log.")
    parser.add_argument("logs", nargs="+",
                        help="path(s) to JSONL run logs")
    parser.add_argument("--check", action="store_true",
                        help="exit nonzero on wire mismatch, drift over "
                             "budget, replay disagreement, or potential "
                             "ascent")
    parser.add_argument("--json", action="store_true",
                        help="emit machine-readable per-run summaries")
    parser.add_argument("--trace", default=None, metavar="OUT.json",
                        help="also write the logs' phase spans as a "
                             "Chrome/Perfetto trace")
    args = parser.parse_args(argv)

    # run ids are per-recorder (r0000, r0001, ...), so distinct logs can
    # reuse them — namespace by log file when reporting several at once
    # or the replays would merge unrelated runs.
    events = []
    for log in args.logs:
        batch = read_jsonl(log)
        if len(args.logs) > 1:
            stem = os.path.splitext(os.path.basename(log))[0]
            for event in batch:
                event["run"] = f"{stem}:{event['run']}"
        events.extend(batch)
    if args.trace:
        from .sinks import write_chrome_trace
        write_chrome_trace(events, args.trace)
    runs = split_runs(events)
    if not runs:
        print("empty log")
        return 1 if args.check else 0
    failures: list[str] = []
    for run_events in runs.values():
        summary = replay_run(run_events)
        if args.json:
            payload = {k: v for k, v in summary.items()
                       if k not in ("cv_trace", "loads", "phases")}
            payload["cv_first"] = (float(summary["cv_trace"][0])
                                   if summary["cv_trace"].size else None)
            payload["cv_last"] = (float(summary["cv_trace"][-1])
                                  if summary["cv_trace"].size else None)
            print(json.dumps(payload, default=str))
        else:
            print(render(summary))
        failures.extend(check_run(summary))
    if failures:
        print("\nCHECK FAILURES:", file=sys.stderr)
        for failure in failures:
            print(f"  {failure}", file=sys.stderr)
    return 1 if (args.check and failures) else 0


if __name__ == "__main__":
    sys.exit(main())
