"""The Recorder: event buffering, phase timing, device-row draining
(DESIGN.md §14.2–§14.3).

The Recorder is the single object an instrumented entry point needs: it
assigns run ids, buffers every event in ``self.events`` (the canonical
in-process stream), fans events out to attached sinks, times wall-clock
phases, and bridges device→host telemetry.

Host-side only: this module imports numpy and the standard library —
never JAX.  All potentially-hot device work stays in the instrumented
modules; what crosses here is either post-run arrays (trace ingestion)
or the buffered rows of a ``jax.debug.callback`` stream.

Device-row bridge
-----------------
``lax.while_loop`` runs (``repro.core.refine.refine``) cannot return
per-turn arrays, so with telemetry enabled the loop body fires one
``jax.debug.callback`` per turn at the recorder's bound method
:meth:`Recorder._on_turn_row`.  The callback only appends raw numpy
scalars to a buffer — no JSON, no sink I/O on the callback thread — and
the entry-point wrapper drains the buffer *after* ``block_until_ready``,
sorting rows by turn index (debug callbacks are unordered) before
emitting ``turn`` events.  Bound methods compare equal across attribute
accesses, so passing ``recorder._on_turn_row`` as a jit-static argument
re-uses one compile cache entry per recorder instance.

Hashing: a Recorder is hashable *by identity* (no ``__eq__``), which is
what lets instrumented entry points accept it as a jit-static argument
without ever baking its mutable state into a trace.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Iterable, Sequence

import numpy as np

from .events import make_event

# Standing accuracy budget for carried quantities (ROADMAP contract).
DRIFT_BUDGET = 1e-3


class Recorder:
    """Buffers typed telemetry events and fans them out to sinks."""

    def __init__(self, sinks: Sequence = (), tol: float = 1e-6):
        self.sinks = list(sinks)
        self.events: list[dict] = []
        self.tol = float(tol)
        self._next_run = 0
        self._last_run: str | None = None
        self._rows: list[tuple] = []
        self._tick_rows: list[tuple] = []
        self._refine_rows: list[tuple] = []

    # ------------------------------------------------------------------
    # core emission
    # ------------------------------------------------------------------
    def new_run(self, runtime: str, **meta) -> str:
        """Open a run; returns its id (``r0000``, ``r0001``, ...)."""
        run = f"r{self._next_run:04d}"
        self._next_run += 1
        self._last_run = run
        self.emit("run_start", run, runtime=runtime, **meta)
        return run

    def emit(self, kind: str, run: str, **fields) -> dict:
        event = make_event(kind, run, **fields)
        self.events.append(event)
        for sink in self.sinks:
            sink.write(event)
        return event

    def flush(self) -> None:
        for sink in self.sinks:
            sink.flush()

    def close(self) -> None:
        for sink in self.sinks:
            close = getattr(sink, "close", None)
            if close is not None:
                close()

    @contextmanager
    def phase(self, name: str, run: str | None = None):
        """Wall-clock a span; emits one ``phase`` event on exit.

        If the wrapped block raises (e.g. a jit failure before
        ``block_until_ready``), the span is still closed, a terminal
        ``run_aborted`` event records the error, and the sinks are
        flushed — everything buffered up to the abort survives on disk
        instead of being lost with the process (DESIGN.md §15.6)."""
        t0 = time.perf_counter()
        try:
            yield
        except BaseException as exc:
            rid = run or self._last_run or "r----"
            self.emit("phase", rid, name=name, ts=t0,
                      dur=time.perf_counter() - t0)
            self.emit("run_aborted", rid, error=repr(exc),
                      pending_rows=len(self._rows) + len(self._tick_rows)
                      + len(self._refine_rows))
            self.flush()
            raise
        else:
            self.emit("phase", run or self._last_run or "r----",
                      name=name, ts=t0, dur=time.perf_counter() - t0)

    # ------------------------------------------------------------------
    # device-row bridge (jax.debug.callback target)
    # ------------------------------------------------------------------
    def _on_turn_row(self, *cols) -> None:
        """Per-turn callback target: buffer raw scalars, nothing else."""
        self._rows.append(tuple(np.asarray(c) for c in cols))

    def _on_tick_row(self, *cols) -> None:
        """Per-DES-tick callback target (trace_stride cadence)."""
        self._tick_rows.append(tuple(np.asarray(c) for c in cols))

    def _on_refine_row(self, *cols) -> None:
        """Per-DES-refinement-round callback target."""
        self._refine_rows.append(tuple(np.asarray(c) for c in cols))

    def begin_rows(self) -> None:
        self._rows = []
        self._tick_rows = []
        self._refine_rows = []

    def take_rows(self) -> list[tuple]:
        rows, self._rows = self._rows, []
        return rows

    def record_des_rows(self, run: str) -> int:
        """Emit ``tick`` + ``des_refine`` events from the drained DES
        callback buffers (sorted by tick — callbacks are unordered).

        Tick rows are ``(t, gvt, processed, rollbacks, refines, moves,
        mean_len, wload_cv, segment, frozen)``; refine rows are
        ``(t, moves, frozen)`` — one per executed refinement round.
        """
        tick_rows, self._tick_rows = self._tick_rows, []
        refine_rows, self._refine_rows = self._refine_rows, []
        for (t, gvt, processed, rollbacks, refines, moves, mean_len,
             wload_cv, segment, frozen) in sorted(
                 tick_rows, key=lambda r: int(r[0])):
            self.emit("tick", run, t=int(t), gvt=float(gvt),
                      processed=int(processed), rollbacks=int(rollbacks),
                      refines=int(refines), moves=int(moves),
                      mean_len=float(mean_len), wload_cv=float(wload_cv),
                      segment=int(segment), frozen=int(frozen))
        for (t, moves, frozen) in sorted(refine_rows,
                                         key=lambda r: int(r[0])):
            self.emit("des_refine", run, t=int(t), moves=int(moves),
                      frozen=int(frozen))
        return len(tick_rows) + len(refine_rows)

    def record_turn_rows(self, run: str, rows: Iterable[tuple],
                         node_weights, *, carried: bool = True,
                         batch=None) -> int:
        """Emit ``turn`` events from drained device rows.

        Each row is ``(t, machine, moved, node, source, dest, gain, c0,
        ct0, raw_gain)`` as produced by the instrumented while-loop body.
        Rows are sorted by turn index (callbacks are unordered) before
        emission.
        """
        b = np.asarray(node_weights)
        rows = sorted(rows, key=lambda r: int(r[0]))
        for (t, machine, moved, node, source, dest, gain, c0, ct0,
             raw_gain) in rows:
            self._emit_turn(run, int(t), int(machine), bool(moved),
                            int(node), int(source), int(dest), float(gain),
                            float(c0) if carried else None,
                            float(ct0) if carried else None,
                            float(raw_gain), b, batch)
        return len(rows)

    # ------------------------------------------------------------------
    # post-run trace ingestion (scan entry points, distributed drivers)
    # ------------------------------------------------------------------
    def record_trace(self, run: str, trace, node_weights, num_machines: int,
                     *, raw_gain=None, carried: bool = True,
                     batch=None) -> int:
        """Emit ``turn`` events from a ``refine_traced``-shape ``Trace``.

        Works on any object with ``moved/node/source/dest/gain/c0/ct0/
        active`` arrays (the core and distributed traced drivers share
        the shape).  Only active turns are emitted; the sequential
        round-robin convention fixes the acting machine as ``t % K``.
        ``raw_gain`` (the θ-free best gain, from the telemetry side
        output) enables hysteresis-vs-satisfied rejection labels.
        """
        b = np.asarray(node_weights)
        active = np.asarray(trace.active)
        moved = np.asarray(trace.moved)
        node = np.asarray(trace.node)
        source = np.asarray(trace.source)
        dest = np.asarray(trace.dest)
        gain = np.asarray(trace.gain)
        c0 = np.asarray(trace.c0)
        ct0 = np.asarray(trace.ct0)
        raw = None if raw_gain is None else np.asarray(raw_gain)
        count = 0
        for t in range(moved.shape[0]):
            if not active[t]:
                continue
            self._emit_turn(run, t, t % int(num_machines), bool(moved[t]),
                            int(node[t]), int(source[t]), int(dest[t]),
                            float(gain[t]),
                            float(c0[t]) if carried else None,
                            float(ct0[t]) if carried else None,
                            None if raw is None else float(raw[t]),
                            b, batch)
            count += 1
        return count

    def _emit_turn(self, run, t, machine, moved, node, source, dest, gain,
                   c0, ct0, raw_gain, b, batch) -> None:
        if moved:
            reject = None
        elif raw_gain is None:
            reject = "unknown"
        else:
            reject = "hysteresis" if raw_gain > self.tol else "satisfied"
        fields = dict(t=t, machine=machine, moved=moved,
                      node=node if moved else None,
                      source=source if moved else None,
                      dest=dest if moved else None,
                      gain=gain if moved else None,
                      weight=float(b[node]) if moved else None,
                      c0=c0, ct0=ct0, reject=reject)
        if raw_gain is not None and np.isfinite(raw_gain):
            fields["raw_gain"] = float(raw_gain)
        if batch is not None:
            fields["batch"] = int(batch)
        self.emit("turn", run, **fields)

    def record_sweeps(self, run: str, c0s, ct0s, active, movers=None,
                      batch=None) -> int:
        """Emit ``sweep`` events from simultaneous-mode per-sweep outputs."""
        c0s = np.asarray(c0s)
        ct0s = np.asarray(ct0s)
        act = np.asarray(active)
        mv = None if movers is None else np.asarray(movers)
        count = 0
        for t in range(act.shape[0]):
            if not act[t]:
                continue
            fields = dict(t=t, movers=-1 if mv is None else int(mv[t]),
                          c0=float(c0s[t]), ct0=float(ct0s[t]),
                          active=bool(act[t]))
            if batch is not None:
                fields["batch"] = int(batch)
            self.emit("sweep", run, **fields)
            count += 1
        return count

    # ------------------------------------------------------------------
    # run closure, drift, wire reconciliation
    # ------------------------------------------------------------------
    def record_result(self, run: str, result, *, wall: float | None = None,
                      c0=None, ct0=None,
                      drift_budget: float = DRIFT_BUDGET, **extra) -> None:
        """Emit the ``drift`` check and the closing ``run_end`` event.

        ``result`` is any ``RefineResult``-shaped object (duck-typed:
        ``num_moves/num_turns/converged/loads/aggregate_drift``).
        ``extra`` fields ride on the ``run_end`` verbatim — fault-
        injected runs attach ``recovered``/``recovery_drift``
        (DESIGN.md §15.6)."""
        drift = float(np.asarray(result.aggregate_drift))
        self.emit("drift", run, value=drift, budget=drift_budget,
                  ok=drift <= drift_budget)
        fields = dict(num_moves=int(np.asarray(result.num_moves)),
                      num_turns=int(np.asarray(result.num_turns)),
                      converged=bool(np.asarray(result.converged)),
                      loads=np.asarray(result.loads),
                      aggregate_drift=drift)
        if wall is not None:
            fields["wall"] = float(wall)
        if c0 is not None:
            fields["c0"] = float(c0)
        if ct0 is not None:
            fields["ct0"] = float(ct0)
        fields.update(extra)
        self.emit("run_end", run, **fields)

    def record_wire(self, run: str, check) -> None:
        """Emit a ``wire`` event from an ``accounting.WireCheck``."""
        self.emit("wire", run, rounds=int(check.rounds),
                  measured_payload=int(check.measured_payload),
                  predicted_payload=int(check.predicted_payload),
                  measured_setup=int(check.measured_setup),
                  predicted_setup=int(check.predicted_setup),
                  ok=bool(check.ok))

    # ------------------------------------------------------------------
    # convenience views
    # ------------------------------------------------------------------
    def events_for(self, run: str) -> list[dict]:
        return [e for e in self.events if e["run"] == run]
