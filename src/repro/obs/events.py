"""Typed telemetry event schema (DESIGN.md §14.1).

Every event is a flat JSON-serializable dict with two mandatory keys —
``kind`` (one of :data:`EVENT_KINDS`) and ``run`` (the recorder-assigned
run id) — plus the kind's required fields below and any number of
optional extras.  Field values are plain Python scalars / lists by the
time they reach a sink; :func:`make_event` normalizes numpy/JAX scalars.

Kinds
-----
``run_start``
    Opens a run.  ``runtime`` names the entry point (``refine``,
    ``refine_traced``, ``refine_simultaneous``, ``distributed``,
    ``distributed_traced``, ``distributed_simultaneous``, ``shard_map``,
    ``des``, ``sweep``); ``loads`` carries the initial (K,) machine
    loads and ``speeds`` the (K,) machine speeds so the report CLI can
    replay weighted-load CV from the move stream alone.
``turn``
    One sequential refinement turn.  ``moved`` is the accept bit; on
    acceptance ``node``/``source``/``dest``/``gain``/``weight`` describe
    the move; on rejection ``reject`` classifies it (``"hysteresis"``
    when the raw best gain cleared ``tol`` but the θ-netted gain did
    not, else ``"satisfied"``).  ``c0``/``ct0`` are the carried global
    potentials *after* the turn (NaN when the variant does not carry
    them).  ``batch`` tags the sweep element for vmapped runs.
``sweep``
    One §4.5 simultaneous sweep: ``movers`` nodes moved, post-sweep
    potentials, ``active`` mirrors the trace's activity bit.
``tick``
    One DES tick at the engine's ``trace_stride`` cadence: committed
    ``gvt``, cumulative ``processed``/``rollbacks``/``refines``/
    ``moves``, mean backlog ``mean_len``, per-machine weighted-load CV
    ``wload_cv``, current speed-schedule ``segment`` (-1 when no
    schedule), and ``frozen`` migration-frozen LPs.
``des_refine``
    One in-situ repartition round: ``moves`` accepted this round,
    ``frozen`` LPs pinned by the migration freeze.
``wire``
    Measured-vs-predicted exchange bytes for a distributed run:
    ``rounds``, ``measured_payload``/``predicted_payload`` (per-turn
    candidate + trace partials), ``measured_setup``/``predicted_setup``,
    and the reconciliation verdict ``ok``.
``drift``
    Carried-vs-recomputed aggregate drift (``RefineResult
    .aggregate_drift``) against the standing ``budget``.
``phase``
    Wall-clock span: ``name``, start ``ts`` and duration ``dur`` in
    seconds (exported to Chrome trace / Perfetto by the sinks).
``element``
    Per-batch-element reduction of a sweep/fleet: the §12.5 headline
    stats for element ``batch``.
``fault_injected``
    One injected fault (DESIGN.md §15): round ``t``, target ``shard``,
    and the ``fault`` class (``"down"``, ``"omit"``, ``"lost"``,
    ``"dup"``, ``"corrupt"``).
``exchange_retry``
    A candidate exchange lost on the wire at round ``t``: the sender
    ``shard``, how many bounded ``attempts`` the retry loop spent, and
    whether the candidate was ultimately ``delivered`` (else the round
    proceeds without it, stale).
``staleness``
    A shard acting on an out-of-date aggregate: round ``t``, ``shard``,
    its staleness ``lag`` (rounds since the last accepted exchange),
    and whether the bounded-staleness rule has ``quarantined`` it
    (lag > max_staleness, DESIGN.md §15.2).
``repair``
    One self-healing repair action: round ``t``, ``action``
    (``"column"`` for an in-run column repair, ``"audit"`` for the
    end-of-run reconciliation), the observed pre-repair ``drift``, and
    the number of aggregate ``cols`` patched (both ``None`` when the
    driver only knows the repair schedule, not its measurements).
``run_aborted``
    Terminal event flushed when the wrapped run raised before its
    events could be finalized (recorder ``finally`` path): ``error``
    is the exception's ``repr``.
``run_end``
    Closes a run with the final counters and, when available, final
    potentials and loads.  Fault-injected runs add ``recovered`` and
    ``recovery_drift`` (the recover-or-raise verdict, DESIGN.md §15).
"""
from __future__ import annotations

from typing import Any

EVENT_KINDS: dict[str, tuple[str, ...]] = {
    "run_start": ("runtime",),
    "turn": ("t", "moved", "c0", "ct0"),
    "sweep": ("t", "movers", "c0", "ct0", "active"),
    "tick": ("t", "gvt", "processed", "rollbacks", "refines", "moves",
             "mean_len", "wload_cv", "segment", "frozen"),
    "des_refine": ("t", "moves", "frozen"),
    "wire": ("rounds", "measured_payload", "predicted_payload",
             "measured_setup", "predicted_setup", "ok"),
    "drift": ("value", "budget"),
    "phase": ("name", "ts", "dur"),
    "element": ("batch",),
    "fault_injected": ("t", "shard", "fault"),
    "exchange_retry": ("t", "shard", "attempts", "delivered"),
    "staleness": ("t", "shard", "lag", "quarantined"),
    "repair": ("t", "action", "drift", "cols"),
    "run_aborted": ("error",),
    "run_end": (),
}


def _plain(value: Any) -> Any:
    """Normalize numpy/JAX scalars and small arrays to JSON-native types."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, (list, tuple)):
        return [_plain(v) for v in value]
    if hasattr(value, "ndim"):            # numpy / JAX array or scalar
        if value.ndim == 0:
            item = value.item()
            return _plain(item)
        return [_plain(v) for v in value.tolist()]
    if hasattr(value, "item"):            # numpy scalar types
        return value.item()
    return value


def make_event(kind: str, run: str, **fields: Any) -> dict:
    """Build (and validate) one event dict with normalized field values."""
    if kind not in EVENT_KINDS:
        raise ValueError(f"unknown event kind {kind!r}; "
                         f"expected one of {sorted(EVENT_KINDS)}")
    event = {"kind": kind, "run": run}
    for key, value in fields.items():
        event[key] = _plain(value)
    missing = [f for f in EVENT_KINDS[kind] if f not in event]
    if missing:
        raise ValueError(f"event kind {kind!r} missing required "
                         f"fields {missing}")
    return event


def validate_event(event: dict) -> dict:
    """Check an already-built dict (e.g. re-read from JSONL); returns it."""
    kind = event.get("kind")
    if kind not in EVENT_KINDS:
        raise ValueError(f"unknown event kind {kind!r}")
    if "run" not in event:
        raise ValueError("event missing 'run'")
    missing = [f for f in EVENT_KINDS[kind] if f not in event]
    if missing:
        raise ValueError(f"event kind {kind!r} missing required "
                         f"fields {missing}")
    return event
