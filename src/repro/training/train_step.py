"""Train step: loss -> grad -> AdamW, with microbatching and the planner hook.

The step is a pure function suitable for jax.jit with NamedSharding
in/out-shardings (repro/launch/train.py and dryrun.py decide those).
Microbatching (gradient accumulation) runs as a lax.scan over microbatch
slices so activation memory scales with the microbatch, not the global
batch.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models import forward_train
from ..models.config import ModelConfig
from . import optimizer as opt

Array = jax.Array


class TrainState(NamedTuple):
    params: dict
    opt: opt.AdamWState
    step: Array
    # cumulative router stats fed to the game-theoretic expert planner
    expert_load: Array      # (E,) or (1,)
    coactivation: Array     # (E, E) or (1, 1)


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    from ..models import init_params
    params = init_params(cfg, key)
    e = max(cfg.num_experts, 1)
    return TrainState(
        params=params,
        opt=opt.adamw_init(params),
        step=jnp.zeros((), jnp.int32),
        expert_load=jnp.zeros((e,), jnp.float32),
        coactivation=jnp.zeros((e, e), jnp.float32),
    )


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # cosine | wsd
    wsd_stable: int = 700
    wsd_decay: int = 200
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    microbatches: int = 1             # gradient accumulation factor


def _lr(hyper: TrainHyper, step):
    if hyper.schedule == "wsd":
        return opt.wsd_schedule(step, peak_lr=hyper.peak_lr,
                                warmup=hyper.warmup, stable=hyper.wsd_stable,
                                decay=hyper.wsd_decay)
    return opt.cosine_schedule(step, peak_lr=hyper.peak_lr,
                               warmup=hyper.warmup, total=hyper.total_steps)


def make_train_step(cfg: ModelConfig, hyper: TrainHyper) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = forward_train(params, cfg, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulate(params, batch):
        m = hyper.microbatches
        if m == 1:
            return single(params, batch)
        sliced = jax.tree.map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)

        def body(carry, micro):
            loss_acc, metrics_acc, grads_acc = carry
            loss, metrics, grads = single(params, micro)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            metrics_acc = jax.tree.map(jnp.add, metrics_acc, metrics)
            return (loss_acc + loss, metrics_acc, grads_acc), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
        first = jax.tree.map(lambda x: x[0], sliced)
        loss0, metrics0, grads0 = single(params, first)
        rest = jax.tree.map(lambda x: x[1:], sliced)
        (loss, metrics, grads), _ = jax.lax.scan(
            body, (loss0, metrics0, grads0), rest)
        inv = 1.0 / m
        return (loss * inv,
                jax.tree.map(lambda x: x * inv, metrics),
                jax.tree.map(lambda g: g * inv, grads))

    def train_step(state: TrainState, batch: dict):
        loss, metrics, grads = accumulate(state.params, batch)
        lr = _lr(hyper, state.step)
        new_params, new_opt, gnorm = opt.adamw_update(
            grads, state.opt, state.params, lr,
            weight_decay=hyper.weight_decay, clip_norm=hyper.clip_norm)
        # exponential-moving router stats for the expert partition planner
        decay = 0.9
        new_state = TrainState(
            params=new_params, opt=new_opt, step=state.step + 1,
            expert_load=decay * state.expert_load
            + (1 - decay) * metrics["expert_load"],
            coactivation=decay * state.coactivation
            + (1 - decay) * metrics["coactivation"],
        )
        out_metrics = {"loss": loss, "ce": metrics["ce"],
                       "aux_loss": metrics["aux_loss"],
                       "grad_norm": gnorm, "lr": lr}
        return new_state, out_metrics

    return train_step
