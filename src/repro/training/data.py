"""Synthetic data pipeline: deterministic, seekable, shardable.

Tokens follow a Zipf-like marginal with short-range Markov structure, so
cross-entropy genuinely decreases during the example training runs (a
uniform stream would pin the loss at log V).  ``synthetic_batch`` is
pure-functional in (config, step) — restart-safe resumption needs no data
state in checkpoints, and each data-parallel host slices its own rows.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    zipf_a: float = 1.2
    markov_period: int = 16
    seed: int = 0
    input_kind: str = "tokens"
    d_model: int = 0                  # for embeddings-input archs


def synthetic_batch(cfg: SyntheticDataConfig, step: int) -> dict:
    """Batch for ``step`` (host-side numpy -> jnp)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    v = cfg.vocab_size
    ranks = np.arange(1, v + 1, dtype=np.float64)
    probs = ranks ** (-cfg.zipf_a)
    probs /= probs.sum()
    base = rng.choice(v, size=(cfg.global_batch, cfg.seq_len), p=probs)
    # short-range structure: every markov_period-th token repeats its
    # predecessor, giving the model something learnable
    idx = np.arange(cfg.seq_len)
    mask = (idx % cfg.markov_period) == (cfg.markov_period - 1)
    base[:, 1:][:, mask[1:]] = base[:, :-1][:, mask[1:]]
    tokens = base.astype(np.int32)
    targets = np.roll(tokens, -1, axis=1)
    targets[:, -1] = tokens[:, 0]
    batch = {"targets": jnp.asarray(targets)}
    if cfg.input_kind == "embeddings":
        # modality stub: deterministic pseudo-embeddings derived from ids
        emb_rng = np.random.default_rng(cfg.seed + 1)
        table = emb_rng.standard_normal((v, cfg.d_model)).astype(np.float32)
        batch["inputs"] = jnp.asarray(table[tokens])
    else:
        batch["inputs"] = jnp.asarray(tokens)
    return batch
