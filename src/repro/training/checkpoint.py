"""Checkpoint / restore with atomic commit — the fault-tolerance substrate.

Layout:  <dir>/step_<n>/arrays.npz + manifest committed via atomic rename,
so a crash mid-save can never corrupt the latest checkpoint.  ``restore``
finds the newest complete step; the train driver calls it on startup, which
is the whole restart story: kill the process anywhere, relaunch, continue
(tests/test_training.py proves bitwise-identical continuation).

On a real multi-host pod each host writes only its addressable shards and
restore re-shards via jax.make_array_from_single_device_arrays; the single-
host container exercises the same code path with one shard.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import numpy as np

import jax
import jax.numpy as jnp


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(directory: str, step: int, state) -> str:
    """Atomically persist ``state`` (any pytree of arrays) for ``step``."""
    os.makedirs(directory, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(state)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_save_")
    try:
        arrays = {f"a{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "paths": paths,
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "shapes": [list(np.asarray(l).shape) for l in leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(directory, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                 # atomic commit
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, like, step: int | None = None):
    """Restore into the structure of ``like`` (shapes/dtypes validated).

    Returns (state, step) or (None, None) when nothing to restore.
    """
    if step is None:
        step = latest_step(directory)
    if step is None:
        return None, None
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    paths, leaves, treedef = _flatten_with_paths(like)
    assert paths == manifest["paths"], "checkpoint/state structure mismatch"
    restored = []
    for i, leaf in enumerate(leaves):
        arr = data[f"a{i}"]
        want = np.asarray(leaf)
        assert list(arr.shape) == list(want.shape), \
            f"shape mismatch at {paths[i]}: {arr.shape} vs {want.shape}"
        restored.append(jnp.asarray(arr.astype(want.dtype)))
    return treedef.unflatten(restored), step
