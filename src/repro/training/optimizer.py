"""Pure-JAX AdamW + LR schedules (cosine and MiniCPM's WSD).

No optax dependency — moments are plain pytrees so the sharding rules can
annotate them exactly like parameters (ZeRO-style: optimizer state shards
with its parameter).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    count: Array


def adamw_init(params, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamWState, params, lr, *,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state).  Global-norm clipping included."""
    count = state.count + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** count.astype(jnp.float32))
        vhat = v / (1 - b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + eps)
        decay = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(mu=new_m, nu=new_v, count=count), gnorm


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = peak_lr * (min_ratio + (1 - min_ratio)
                     * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def wsd_schedule(step, *, peak_lr: float, warmup: int, stable: int,
                 decay: int, min_ratio: float = 0.01):
    """MiniCPM's Warmup-Stable-Decay: linear warmup, long flat stage, short
    exponential-ish (here linear) decay tail [arXiv:2404.06395]."""
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    in_decay = step > (warmup + stable)
    frac = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0, 1)
    tail = peak_lr * (1 - (1 - min_ratio) * frac)
    return jnp.where(step < warmup, warm,
                     jnp.where(in_decay, tail, peak_lr))
