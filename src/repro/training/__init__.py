from .optimizer import (  # noqa: F401
    AdamWState,
    adamw_init,
    adamw_update,
    cosine_schedule,
    wsd_schedule,
)
from .train_step import TrainState, init_train_state, make_train_step  # noqa: F401
from .data import synthetic_batch, SyntheticDataConfig  # noqa: F401
from .checkpoint import latest_step, restore, save  # noqa: F401
from .compression import compress_int8, decompress_int8  # noqa: F401
