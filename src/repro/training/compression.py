"""Gradient compression for the data-parallel all-reduce.

Int8 symmetric quantization with error feedback [1-bit Adam lineage]: the
quantization residual is carried to the next step so compression bias does
not accumulate.  ``compressed_psum`` runs under any named axis — a
``shard_map`` over ('pod', 'data') on the production mesh, or ``vmap``
with an axis name in tests (tests/test_training.py proves the mean is
recovered and the error feedback kills the bias).

Deployment note: the jit/GSPMD train step lets XLA insert the gradient
all-reduce implicitly, so compression applies on the manual-collective
path: wrap the per-shard grad computation in ``shard_map`` over the data
axes and call ``compressed_psum`` before the optimizer.  On the 2x16x16
production mesh the 'pod'-axis hop is the slow inter-pod link — the one
place the 4x payload reduction moves the collective roofline term
(see ``benchmarks/roofline.py`` / BENCH_roofline.json).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


def compress_int8(x: Array):
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis_name: str, errors):
    """psum with int8 error-feedback compression along ``axis_name``.

    grads/errors: pytrees (errors same structure, f32).  Returns
    (mean_grads, new_errors).
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = compress_int8(g32)
        deq = decompress_int8(q, scale)
        new_e = g32 - deq
        # int8 payload all-reduce; scales all-reduce separately (K floats)
        total = jax.lax.psum(deq, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total / n).astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_errors(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
