"""Run provenance: code version + toolchain + hardware (DESIGN.md §14.5).

One shared implementation stamped into every machine-readable artifact
the repo emits — BENCH_*.json (``benchmarks.common.write_bench_json``)
and the analysis CLI's findings.json — so a number is never compared
against one produced by a different commit, jax version, or device kind
without noticing.
"""
from __future__ import annotations

import datetime
import os
import platform
import subprocess

import jax

__all__ = ["git_sha", "provenance", "REPO_ROOT"]

# src/repro/provenance.py -> repo root is two levels above src
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def git_sha(root: str | None = None) -> str | None:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             cwd=root or REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        return None


def provenance(root: str | None = None) -> dict:
    """What produced an artifact: code version + toolchain + hardware."""
    import jaxlib
    dev = jax.devices()[0]
    return {
        "git_sha": git_sha(root),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
