from .baselines import (  # noqa: F401
    greedy_load_partition,
    kernighan_lin_refine,
    nandy_loucks_refine,
    random_partition,
    spectral_bisection,
)
