"""Comparison baselines from the paper's §2 literature review.

These are the centralized heuristics the paper positions itself against:

  * ``random_partition``       — uniform assignment (sanity floor).
  * ``greedy_load_partition``  — longest-processing-time list scheduling:
                                 balances load, ignores the cut (the classic
                                 load-balancing-only strawman).
  * ``kernighan_lin_refine``   — [Kernighan & Lin 1970] pairwise exchange
                                 refinement on the cut, K-way via pair sweeps.
  * ``spectral_bisection``     — [Pothen et al. 1990] recursive Fiedler-vector
                                 bisection (dense eigendecomposition).
  * ``nandy_loucks_refine``    — [Nandy & Loucks 1993], the paper's closest
                                 prior work: gain-based migration minimizing
                                 only the cut, each node allowed to migrate
                                 at most once ("forced convergence").

All are host-side (numpy) reference implementations — they exist to be
*measured against*, not to be fast; benchmarks compare their C_0 / Ct_0 /
simulation-time against the game-theoretic refinement.
"""
from __future__ import annotations

import numpy as np


def random_partition(n: int, k: int, seed) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, size=n).astype(np.int32)


def greedy_load_partition(node_weights: np.ndarray, speeds: np.ndarray) -> np.ndarray:
    """LPT list scheduling: heaviest node to the machine with most headroom."""
    n = node_weights.shape[0]
    k = speeds.shape[0]
    order = np.argsort(-node_weights)
    loads = np.zeros(k)
    out = np.zeros(n, np.int32)
    for i in order:
        m = int(np.argmin((loads + node_weights[i]) / speeds))
        out[i] = m
        loads[m] += node_weights[i]
    return out


def _cut_gain(adj: np.ndarray, r: np.ndarray, i: int, dest: int) -> float:
    """Cut decrease if node i moves to dest (positive = improvement)."""
    internal_new = adj[i, r == dest].sum()
    internal_old = adj[i, r == r[i]].sum()
    return float(internal_new - internal_old)


def kernighan_lin_refine(adj: np.ndarray, assignment: np.ndarray,
                         max_passes: int = 4) -> np.ndarray:
    """K-way K-L: for every machine pair, greedily swap the best node pair
    while positive gain exists (bounded passes)."""
    r = assignment.astype(np.int32).copy()
    k = int(r.max()) + 1
    for _ in range(max_passes):
        improved = False
        for a in range(k):
            for b in range(a + 1, k):
                ia = np.flatnonzero(r == a)
                ib = np.flatnonzero(r == b)
                if ia.size == 0 or ib.size == 0:
                    continue
                # gains of single moves
                ga = np.array([_cut_gain(adj, r, i, b) for i in ia])
                gb = np.array([_cut_gain(adj, r, j, a) for j in ib])
                bi, bj = int(np.argmax(ga)), int(np.argmax(gb))
                i, j = int(ia[bi]), int(ib[bj])
                # pair swap gain corrects for the (i, j) edge counted twice
                gain = ga[bi] + gb[bj] - 2.0 * adj[i, j]
                if gain > 1e-9:
                    r[i], r[j] = b, a
                    improved = True
        if not improved:
            break
    return r


def spectral_bisection(adj: np.ndarray, k: int) -> np.ndarray:
    """Recursive Fiedler bisection down to k parts (k must be a power of 2
    for clean halving; otherwise the last level splits unevenly)."""
    def bisect(nodes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        sub = adj[np.ix_(nodes, nodes)]
        deg = sub.sum(1)
        lap = np.diag(deg) - sub
        vals, vecs = np.linalg.eigh(lap)
        fiedler = vecs[:, 1] if vecs.shape[1] > 1 else vecs[:, 0]
        med = np.median(fiedler)
        left = nodes[fiedler <= med]
        right = nodes[fiedler > med]
        if left.size == 0 or right.size == 0:   # degenerate: split by order
            half = nodes.size // 2
            left, right = nodes[:half], nodes[half:]
        return left, right

    parts = [np.arange(adj.shape[0])]
    while len(parts) < k:
        parts.sort(key=lambda p: -p.size)
        left, right = bisect(parts.pop(0))
        parts.extend([left, right])
    out = np.zeros(adj.shape[0], np.int32)
    for m, p in enumerate(parts):
        out[p] = m
    return out


def nandy_loucks_refine(adj: np.ndarray, assignment: np.ndarray) -> np.ndarray:
    """[Nandy & Loucks 1993]: iterative gain-only migration, cut objective,
    each node migrates at most once (the paper's "forced convergence")."""
    r = assignment.astype(np.int32).copy()
    k = int(r.max()) + 1
    n = r.shape[0]
    migrated = np.zeros(n, bool)
    while True:
        best = (0.0, -1, -1)
        for i in range(n):
            if migrated[i]:
                continue
            for dest in range(k):
                if dest == r[i]:
                    continue
                g = _cut_gain(adj, r, i, dest)
                if g > best[0] + 1e-12:
                    best = (g, i, dest)
        if best[1] < 0:
            break
        _, i, dest = best
        r[i] = dest
        migrated[i] = True
    return r
