"""Continuous-batching serving engine (vLLM-style slots, JAX-native).

The engine owns a fixed pool of ``max_batch`` cache slots over a single
batched :class:`~repro.models.transformer.DecodeCache` with *per-slot*
positions, so sequences of different lengths decode together in one jitted
``decode_step`` call (the decode paths broadcast a (B,) position vector).

Scheduling is host-side Python (admission, eviction, queueing — the part a
real cluster does on CPU anyway); all tensor work is two jitted programs:

  * ``_prefill_one``  — B=1 prompt prefill producing a slot-shaped cache,
  * ``_decode_all``   — one token for every active slot.

Inactive slots decode garbage that is masked out on the host — the standard
price of static shapes, and exactly what the ``decode_*`` dry-run shapes
model.  On a pod the same engine runs with the param/cache shardings from
``repro.sharding.rules``; here it runs on CPU with reduced configs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..models import init_cache
from ..models.config import ModelConfig
from ..models.transformer import DecodeCache, decode_step, prefill
from .sampler import greedy

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8           # cache slots
    max_len: int = 512           # per-slot KV/SSM capacity
    eos_id: int = -1             # -1 = never stop on a token
    cache_dtype: str = "bfloat16"


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S,) int32 token ids
    max_new_tokens: int
    # filled by the engine
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _write_slot(batched: DecodeCache, single: DecodeCache, slot: int,
                position) -> DecodeCache:
    """Copy a B=1 cache into slot ``slot`` of the batched cache."""
    def put(dst, src):
        if dst is None:
            return None
        return dst.at[:, slot].set(src[:, 0])

    return DecodeCache(
        kv_k=put(batched.kv_k, single.kv_k),
        kv_v=put(batched.kv_v, single.kv_v),
        ssm_state=put(batched.ssm_state, single.ssm_state),
        ssm_conv=put(batched.ssm_conv, single.ssm_conv),
        position=batched.position.at[slot].set(position),
    )


class ServingEngine:
    """Continuous batching over a fixed slot pool.

    >>> eng = ServingEngine(cfg, params, ServeConfig(max_batch=4, max_len=256))
    >>> eng.submit(Request(0, prompt, max_new_tokens=32))
    >>> stats = eng.run()          # drains the queue
    """

    def __init__(self, cfg: ModelConfig, params: dict, serve: ServeConfig,
                 sampler=greedy):
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.sampler = sampler
        B, S = serve.max_batch, serve.max_len

        cache = init_cache(cfg, B, S, jnp.dtype(serve.cache_dtype))
        # per-slot positions (the decode paths broadcast (B,) positions)
        self.cache = cache._replace(position=jnp.zeros((B,), jnp.int32))
        self.slots: list[Optional[Request]] = [None] * B
        self.budget = np.zeros(B, np.int64)      # remaining new tokens
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.steps = 0
        self.prefills = 0

        def _prefill_one(params, tokens):
            logits, cache = prefill(params, cfg, tokens, max_len=S)
            return logits, cache

        def _decode_all(params, tokens, cache):
            return decode_step(params, cfg, tokens, cache)

        self._prefill = jax.jit(_prefill_one)
        self._decode = jax.jit(_decode_all, donate_argnums=(2,))

    # ----- scheduling --------------------------------------------------
    def submit(self, request: Request) -> None:
        assert request.prompt.ndim == 1 and request.prompt.size >= 1
        assert request.prompt.size + request.max_new_tokens <= self.serve.max_len, \
            "request exceeds slot capacity"
        self.queue.append(request)

    def _admit(self) -> None:
        for slot in range(self.serve.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, single = self._prefill(self.params, tokens)
            first = int(self.sampler(logits)[0])
            req.output.append(first)
            self.cache = _write_slot(self.cache, single, slot,
                                     req.prompt.size)
            self.slots[slot] = req
            self.budget[slot] = req.max_new_tokens - 1
            self.prefills += 1
            if (first == self.serve.eos_id) or self.budget[slot] <= 0:
                self._finish(slot)

    def _finish(self, slot: int) -> None:
        req = self.slots[slot]
        req.done = True
        self.finished.append(req)
        self.slots[slot] = None
        self.budget[slot] = 0

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    # ----- decode loop ---------------------------------------------------
    def step(self) -> None:
        """Admit waiting requests, then decode one token for every slot."""
        self._admit()
        if self.num_active == 0:
            return
        last = np.zeros((self.serve.max_batch, 1), np.int32)
        for slot, req in enumerate(self.slots):
            if req is not None:
                last[slot, 0] = req.output[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(last), self.cache)
        token = np.asarray(self.sampler(logits))
        self.steps += 1
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            t = int(token[slot])
            req.output.append(t)
            self.budget[slot] -= 1
            if t == self.serve.eos_id or self.budget[slot] <= 0:
                self._finish(slot)

    def run(self, max_steps: int = 100_000) -> dict:
        """Drain the queue; returns throughput stats."""
        import time
        t0 = time.time()
        while (self.queue or self.num_active) and self.steps < max_steps:
            self.step()
        wall = time.time() - t0
        toks = sum(len(r.output) for r in self.finished)
        return {"requests": len(self.finished), "decode_steps": self.steps,
                "prefills": self.prefills, "generated_tokens": toks,
                "wall_s": wall,
                "tok_per_s": toks / max(wall, 1e-9)}
