"""Token samplers for the serving engine (pure functions of logits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def greedy(logits: Array) -> Array:
    """logits: (B, 1, V) or (B, V) -> (B,) int32."""
    if logits.ndim == 3:
        logits = logits[:, -1, :]
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_logits(key: Array, logits: Array, *, temperature: float = 1.0,
                  top_k: int = 0) -> Array:
    """Temperature + optional top-k sampling.  logits: (B, 1, V) or (B, V)."""
    if logits.ndim == 3:
        logits = logits[:, -1, :]
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
