from .engine import Request, ServeConfig, ServingEngine  # noqa: F401
from .sampler import greedy, sample_logits  # noqa: F401
