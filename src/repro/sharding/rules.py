"""Sharding rules: param-tree paths -> PartitionSpec.

Strategy (2-D "TP x FSDP" over the ('data', 'model') mesh axes, with the
'pod' axis joining 'data' for batch parallelism on the multi-pod mesh):

  * matmul weights carry tensor parallelism on their TP-natural dim
    ('model') and ZeRO/FSDP on the other dim ('data'), so parameters AND
    Adam moments shard over every chip — the memory story that lets
    qwen3-235b fit 256 x 16 GB.
  * MoE expert stacks shard experts over 'model' (expert parallelism, the
    partition planner permutes along this axis) and d_model over 'data'.
  * small tensors (norms, biases, scalars) replicate.
  * the stacked-layer leading axis is never sharded.

A dim is only sharded when divisible by the axis size; otherwise the rule
falls back to replication on that dim (checked at spec-build time, so every
(arch x mesh) combination yields a valid sharding).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fit(mesh: Mesh, shape, spec_dims):
    """Replace axis entries that do not divide the dim with None."""
    fixed = []
    for size, axis in zip(shape, spec_dims):
        fixed.append(axis if size % _axis_size(mesh, axis) == 0 else None)
    return P(*fixed)


# rules: (path regex, spec dims for the *trailing* dims of the leaf).
# The leading stacked-layer dim (present for everything under blocks/) is
# handled automatically.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$",              ("model", "data")),
    (r"lm_head$",            ("data", "model")),
    (r"attn/wq$",            ("data", "model")),
    (r"attn/wk$",            ("data", "model")),
    (r"attn/wv$",            ("data", "model")),
    (r"attn/wo$",            ("model", "data")),
    (r"attn/b[qkv]$",        ("model",)),
    (r"mlp/gate$",           ("data", "model")),
    (r"mlp/up$",             ("data", "model")),
    (r"mlp/down$",           ("model", "data")),
    (r"moe/router$",         ("data", None)),
    (r"moe/gate$",           ("model", "data", None)),   # (E, d, f)
    (r"moe/up$",             ("model", "data", None)),
    (r"moe/down$",           ("model", None, "data")),   # (E, f, d)
    (r"ssm/in_proj$",        ("data", "model")),
    (r"ssm/out_proj$",       ("model", "data")),
    (r"ssm/conv_w$",         (None, None)),
    (r"ssm/.*$",             (None,)),                   # A_log, dt_bias, D...
    (r".*norm.*$",           (None,)),
]


def _spec_for_path(path: str, leaf, mesh: Mesh, stacked: bool) -> P:
    trailing_ndim = leaf.ndim - (1 if stacked else 0)
    for pattern, dims in _PARAM_RULES:
        if re.search(pattern, path):
            dims = tuple(dims[:trailing_ndim])
            dims = dims + (None,) * (trailing_ndim - len(dims))
            shape = leaf.shape[1:] if stacked else leaf.shape
            spec = _fit(mesh, shape, dims)
            if stacked:
                spec = P(None, *spec)
            return spec
    return P()  # replicate anything unmatched


def _tree_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        yield "/".join(str(getattr(k, "key", k)) for k in path), leaf


def _drop_data_axis(spec: P) -> P:
    """ZeRO-1 parameter layout: keep tensor parallelism ('model'), drop the
    ZeRO/FSDP sharding over the data axes — weights are read locally with
    NO per-layer all-gather; only the optimizer step communicates (grad
    reduce-scatter + param all-gather, once per step)."""
    def fix(ax):
        if ax is None:
            return None
        axes = ax if isinstance(ax, tuple) else (ax,)
        kept = tuple(a for a in axes if a not in ("data", "pod"))
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    return P(*[fix(ax) for ax in tuple(spec)])


def param_specs(cfg: ModelConfig, mesh, params, *,
                strategy: str = "fsdp") -> Any:
    """PartitionSpecs matching the params pytree (no device binding —
    also usable with an AbstractMesh for spec-validation tests).

    strategy:
      * "fsdp"  — weights shard over (data x model); ZeRO-3-style gathers
                  on use (smallest per-chip memory, per-microbatch gather
                  traffic).
      * "zero1" — weights shard over 'model' only (read locally, no
                  gathers); pick when P/tp fits HBM (§Perf hillclimb).
    """
    def assign(path_tuple, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_tuple)
        # everything under blocks/ carries the stacked layer dim
        stacked = path.startswith("blocks")
        spec = _spec_for_path(path, leaf, mesh, stacked)
        if strategy == "zero1":
            spec = _drop_data_axis(spec)
        return spec

    return jax.tree_util.tree_map_with_path(assign, params)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params, *,
                    strategy: str = "fsdp") -> Any:
    """NamedShardings matching the params pytree (works on ShapeDtypeStructs)."""
    specs = param_specs(cfg, mesh, params, strategy=strategy)
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, kind: str = "train") -> P:
    """Batch dim spreads over every data-like axis present in the mesh."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    return P(dp)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch) -> Any:
    dp = batch_spec(mesh)

    def assign(leaf):
        dims = [dp[0] if leaf.shape[0] % _axis_size(mesh, dp[0]) == 0
                else None]
        dims += [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(assign, batch)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache) -> Any:
    """Decode cache: batch over data axes; heads (or cache sequence for MQA
    archs where kv_heads < model-axis size) over 'model'."""
    dp = batch_spec(mesh)
    dp_axis = dp[0]
    model = "model" if "model" in mesh.shape else None

    def assign_named(path_tuple, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_tuple)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if "kv_" in path:
            # (L, B, S, Hkv, D): try heads on model, else sequence on model
            _, b, s, hkv, _ = leaf.shape
            msize = _axis_size(mesh, model)
            if hkv % msize == 0:
                spec = P(None, dp_axis if b % _axis_size(mesh, dp_axis) == 0
                         else None, None, model, None)
            else:
                spec = P(None, dp_axis if b % _axis_size(mesh, dp_axis) == 0
                         else None, model if s % msize == 0 else None,
                         None, None)
            return NamedSharding(mesh, spec)
        if "ssm_state" in path:
            # (L, B, H, P, N): heads over model
            _, b, h, _, _ = leaf.shape
            spec = P(None, dp_axis if b % _axis_size(mesh, dp_axis) == 0
                     else None,
                     model if h % _axis_size(mesh, model) == 0 else None,
                     None, None)
            return NamedSharding(mesh, spec)
        if "ssm_conv" in path:
            _, b, _, c = leaf.shape
            spec = P(None, dp_axis if b % _axis_size(mesh, dp_axis) == 0
                     else None, None,
                     model if c % _axis_size(mesh, model) == 0 else None)
            return NamedSharding(mesh, spec)
        dims = [None] * leaf.ndim
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(assign_named, cache)


def state_shardings(cfg: ModelConfig, mesh: Mesh, state, *,
                    strategy: str = "fsdp") -> Any:
    """TrainState shardings.

    fsdp:  params AND Adam moments shard over (data x model).
    zero1: params shard over 'model' only (local reads, no per-layer
           gathers); Adam moments keep the full (data x model) sharding —
           the optimizer state is the ZeRO-1 sharded part.
    """
    params_sh = param_shardings(cfg, mesh, state.params, strategy=strategy)
    mu_sh = param_shardings(cfg, mesh, state.opt.mu)
    nu_sh = param_shardings(cfg, mesh, state.opt.nu)
    scalar = NamedSharding(mesh, P())
    e_sh = NamedSharding(mesh, P())
    return type(state)(
        params=params_sh,
        opt=type(state.opt)(mu=mu_sh, nu=nu_sh, count=scalar),
        step=scalar,
        expert_load=e_sh,
        coactivation=e_sh,
    )
