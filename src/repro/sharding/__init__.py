from .rules import (  # noqa: F401
    batch_spec,
    cache_shardings,
    param_shardings,
    state_shardings,
)
from .planner import PartitionPlanner, expert_placement, stage_assignment  # noqa: F401
