"""In-model sharding hints (activation partitioning).

``hint(x, ...)`` applies ``with_sharding_constraint`` with axis-presence and
divisibility guards, and silently no-ops when no mesh is active — so model
code stays runnable in plain CPU tests while the SPMD paths get explicit
activation layouts.

Why this exists: without constraints
GSPMD must GUESS how to shard the (heads, head_dim) split of fused QKV
projections.  When the head count does not divide the model axis (yi-34b:
56 heads on a 16-wide axis) it shards head_dim — the attention CONTRACTION
dim — which turns every S x S logits tensor into a partial sum that is
all-reduced: 3 x 120 GB per layer per chip on yi-34b train_4k.  The fix is
sequence-parallel attention: shard q's sequence over 'model' (always
divisible: 4096 % 16 == 0), keep k/v unsharded on the feature dims, and
keep the residual stream sequence-sharded between layers (which also cuts
saved-activation memory 16x).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

DP = "dp"   # sentinel: all data-parallel axes present in the mesh


def mesh_axis_sizes() -> dict | None:
    """{axis: size} of the active mesh (set_mesh or `with mesh:`), or None."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            return dict(am.shape)
    except Exception:
        pass
    try:
        from jax._src import mesh as _mesh_mod
        pm = _mesh_mod.thread_resources.env.physical_mesh
        if pm is not None and pm.axis_names:
            return dict(pm.shape)
    except Exception:
        pass
    return None


def dp_axes(shape: dict) -> tuple:
    return tuple(a for a in ("pod", "data") if a in shape and shape[a] > 1)


def hint(x, *dims):
    """Constrain ``x`` to P(*dims) where valid; no-op without a mesh.

    Each entry of ``dims`` is None, an axis name, a tuple of axis names, or
    the sentinel ``DP`` (all data axes).  Axes missing from the mesh or not
    dividing the dimension fall back to None (replicated on that dim).
    Trailing unspecified dims replicate.

    Set REPRO_NO_HINTS=1 to disable all hints — used to reproduce the
    paper-faithful/unannotated BASELINE measurements
    (``benchmarks/roofline.py``).
    """
    import os
    if os.environ.get("REPRO_NO_HINTS", "0") == "1":
        return x
    shape = mesh_axis_sizes()
    if not shape:
        return x
    spec = []
    for i, d in enumerate(x.shape):
        ax = dims[i] if i < len(dims) else None
        if ax == DP:
            ax = dp_axes(shape) or None
            if ax is not None and len(ax) == 1:
                ax = ax[0]
        if ax is None:
            spec.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        ok = True
        for a in axes:
            if a not in shape:
                ok = False
                break
            size *= shape[a]
        if ok and size > 1 and d % size == 0:
            spec.append(ax)
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:   # no mesh context at lowering — stay functional
        return x
