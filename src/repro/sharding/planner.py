"""PartitionPlanner: the paper's game as a first-class framework feature.

Two production uses (DESIGN.md §4):

  * **Expert placement (EP)** — experts are the LPs: node weight = EMA of
    tokens routed to the expert (dynamic load, from TrainState router
    stats), edge weight = co-activation counts (tokens routed to both
    experts; splitting a strongly co-activated pair across device groups
    costs all-to-all traffic).  Machines = model-axis device groups.  The
    refined Nash assignment is repaired to exactly E/K experts per group
    (weight arrays shard evenly) and emitted as a permutation applied to
    the expert-stacked weight tensors.

  * **Pipeline-stage assignment (PP)** — layers are LPs on a chain: node
    weight = per-layer FLOPs, edge weight = activation bytes.  The refined
    assignment is projected to contiguous stages and compared against the
    O(L^2 K) interval-DP oracle (tests assert the game lands within a few
    percent of optimal).

Both run the *same* refine() the DES simulator uses — one algorithm, three
deployments (the point of the reproduction).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from ..core import costs as game_costs
from ..core.constrained import (contiguous_stage_dp, equalize_cardinality,
                                make_contiguous)
from ..core.problem import PartitionProblem, make_problem
from ..core.refine import refine

Array = jax.Array


def expert_placement(expert_load: Array, coactivation: Array,
                     num_groups: int, *, mu: float = 1.0,
                     current: Array | None = None,
                     framework: str = game_costs.C_FRAMEWORK):
    """Returns (permutation (E,), assignment (E,), stats dict).

    ``permutation[i]`` = expert to place at slot i; slots are contiguous
    per group, matching a ('model',)-sharded leading expert dim.
    """
    e = int(expert_load.shape[0])
    assert e % num_groups == 0, (e, num_groups)
    load = jnp.asarray(expert_load, jnp.float32) + 1e-6
    coact = jnp.asarray(coactivation, jnp.float32)
    # normalize edge weights to the load scale so mu means the same thing
    # across training stages
    denom = jnp.maximum(jnp.max(coact), 1e-6)
    coact = coact * (jnp.max(load) / denom)
    problem = make_problem(coact, load,
                           jnp.full((num_groups,), 1.0, jnp.float32), mu=mu)
    if current is None:
        current = jnp.arange(e, dtype=jnp.int32) % num_groups
    res = refine(problem, current, framework, max_turns=4 * e)
    balanced = equalize_cardinality(problem, res.assignment, framework)
    perm = jnp.argsort(balanced, stable=True).astype(jnp.int32)

    group_load = jnp.zeros((num_groups,), jnp.float32).at[balanced].add(load)
    stats = {
        "imbalance_before": float(jnp.max(
            jnp.zeros((num_groups,), jnp.float32).at[current].add(load))
            / (jnp.sum(load) / num_groups)),
        "imbalance_after": float(jnp.max(group_load)
                                 / (jnp.sum(load) / num_groups)),
        "moves": int(res.num_moves),
    }
    return perm, balanced, stats


def apply_expert_permutation(params: dict, perm: Array) -> dict:
    """Permute the expert-stacked MoE weights (leading dim E after the
    stacked-layer dim) and the router columns to match."""
    def fix(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if "moe/gate" in name or "moe/up" in name or "moe/down" in name:
            return leaf[:, perm] if leaf.ndim == 4 else leaf[perm]
        if "moe/router" in name:
            return leaf[..., perm]
        return leaf

    return jax.tree_util.tree_map_with_path(fix, params)


def stage_assignment(layer_cost, boundary_bytes, num_stages: int, *,
                     mu: float = 1.0,
                     framework: str = game_costs.C_FRAMEWORK):
    """Game-refined contiguous pipeline stages.

    layer_cost: (L,) per-layer FLOPs (or time) estimates.
    boundary_bytes: scalar or (L-1,) activation bytes across each boundary.
    Returns (assignment (L,), game_max_load, dp_max_load).
    """
    layer_cost = jnp.asarray(layer_cost, jnp.float32)
    L = layer_cost.shape[0]
    bb = jnp.broadcast_to(jnp.asarray(boundary_bytes, jnp.float32), (L - 1,))
    adj = jnp.zeros((L, L), jnp.float32)
    idx = jnp.arange(L - 1)
    adj = adj.at[idx, idx + 1].set(bb).at[idx + 1, idx].set(bb)
    # scale cut weights relative to compute so mu stays interpretable
    adj = adj * (jnp.mean(layer_cost) / jnp.maximum(jnp.mean(bb), 1e-9))
    problem = make_problem(adj, layer_cost,
                           jnp.full((num_stages,), 1.0, jnp.float32), mu=mu)
    init = (jnp.arange(L, dtype=jnp.int32) * num_stages) // L
    res = refine(problem, init, framework, max_turns=8 * L)
    game = make_contiguous(res.assignment, num_stages)
    loads = jnp.zeros((num_stages,), jnp.float32).at[game].add(layer_cost)
    dp_assign, dp_max = contiguous_stage_dp(np.asarray(layer_cost),
                                            num_stages)
    return game, float(jnp.max(loads)), dp_max


@dataclasses.dataclass
class PartitionPlanner:
    """Stateful wrapper the train driver calls every ``interval`` steps."""
    num_groups: int
    interval: int = 100
    mu: float = 1.0
    _last_perm: Array | None = None

    def maybe_replan(self, step: int, state):
        """Returns (state, stats|None): permutes expert weights in-place
        when router stats show imbalance."""
        if self.num_groups <= 1 or step == 0 or step % self.interval:
            return state, None
        if jnp.sum(state.expert_load) <= 0:
            return state, None
        perm, assignment, stats = expert_placement(
            state.expert_load, state.coactivation, self.num_groups,
            mu=self.mu)
        if bool(jnp.all(perm == jnp.arange(perm.shape[0]))):
            return state, stats
        new_params = apply_expert_permutation(state.params, perm)
        new_mu = apply_expert_permutation(state.opt.mu, perm)
        new_nu = apply_expert_permutation(state.opt.nu, perm)
        state = state._replace(
            params=new_params,
            opt=state.opt._replace(mu=new_mu, nu=new_nu),
            expert_load=state.expert_load[perm],
            coactivation=state.coactivation[perm][:, perm],
        )
        self._last_perm = perm
        return state, stats
