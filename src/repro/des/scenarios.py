"""Machine-churn scenarios: piecewise-constant per-machine speed schedules.

The paper's cost frameworks carry per-machine speeds ``w_k`` (Eq. 1/6)
precisely because real clusters are not uniform AND not static: machines
slow down (co-tenancy, thermal throttling), fail, and recover while the
workload's hot spots move.  A :class:`SpeedSchedule` is the minimal model
of that churn — a sorted list of wall-clock tick boundaries and, per
segment, the (K,) relative machine speeds in effect (1.0 = nominal; see
DESIGN.md §11).  ``repro.des.engine`` consumes it per tick: busy-time
scales inversely with the resident machine's current speed, and each
refinement round feeds the live speeds into the partition game.

Builders are host-side (numpy); the schedule itself is jnp arrays so
``speeds_at`` traces inside the engine's ``lax.while_loop``.

Speeds are clamped to ``MIN_SPEED`` — a "failed" machine is modeled as
nearly-stopped rather than stopped, both because busy-time divides by
speed and because a truly dead machine needs LP re-homing, which is the
refinement layer's job (the failure scenario is exactly what should
trigger it).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

Array = jax.Array

MIN_SPEED = 0.02   # floor for "failed" machines (busy-time divides by speed)


class SpeedSchedule(NamedTuple):
    """Piecewise-constant machine speeds over wall-clock ticks.

    Segment ``s`` is in effect for ticks in ``[times[s], times[s+1])``
    (the last segment extends forever).  ``times[0]`` must be 0 so every
    tick is covered.
    """
    times: Array    # (S,) int32 — ascending segment-start ticks, times[0]=0
    speeds: Array   # (S, K) float32 — relative speeds, 1.0 = nominal

    @property
    def num_machines(self) -> int:
        return self.speeds.shape[1]


def make_schedule(times, speeds) -> SpeedSchedule:
    """Validate + clamp host-side arrays into a :class:`SpeedSchedule`."""
    times = np.asarray(times, np.int32)
    speeds = np.asarray(speeds, np.float32)
    if times.ndim != 1 or speeds.ndim != 2 or times.shape[0] != speeds.shape[0]:
        raise ValueError(f"shape mismatch: times {times.shape} vs "
                         f"speeds {speeds.shape}")
    if times.shape[0] == 0 or times[0] != 0:
        raise ValueError("times must start at tick 0")
    if np.any(np.diff(times) <= 0):
        raise ValueError("times must be strictly ascending")
    speeds = np.maximum(speeds, MIN_SPEED)
    return SpeedSchedule(times=jnp.asarray(times),
                         speeds=jnp.asarray(speeds))


def segment_at(schedule: SpeedSchedule, tick: Array) -> Array:
    """() i32 — index of the segment in effect at wall-clock ``tick``
    (traceable; the telemetry ``tick`` events carry it so churn phases
    are attributable in a run log, DESIGN.md §14)."""
    idx = jnp.sum((schedule.times <= tick).astype(jnp.int32)) - 1
    return jnp.clip(idx, 0, schedule.times.shape[0] - 1)


def speeds_at(schedule: SpeedSchedule, tick: Array) -> Array:
    """(K,) speeds in effect at wall-clock ``tick`` (traceable)."""
    return schedule.speeds[segment_at(schedule, tick)]


# ---------------------------------------------------------------------------
# scenario builders (host-side)
# ---------------------------------------------------------------------------

def constant(num_machines: int, speeds=None) -> SpeedSchedule:
    """One segment: fixed (possibly heterogeneous) speeds forever."""
    row = np.ones(num_machines, np.float32) if speeds is None \
        else np.asarray(speeds, np.float32)
    return make_schedule([0], row[None, :])


def slowdown(num_machines: int, machine: int, at_tick: int,
             factor: float = 0.25, recover_tick: int | None = None,
             base=None) -> SpeedSchedule:
    """``machine`` drops to ``factor`` of its base speed at ``at_tick``
    (co-tenant / throttling churn), optionally recovering later."""
    base = np.ones(num_machines, np.float32) if base is None \
        else np.asarray(base, np.float32)
    rows, times = [base], [0]
    slow = base.copy()
    slow[machine] = base[machine] * factor
    rows.append(slow)
    times.append(at_tick)
    if recover_tick is not None:
        rows.append(base)
        times.append(recover_tick)
    return make_schedule(times, np.stack(rows))


def failure_recovery(num_machines: int, machine: int, fail_tick: int,
                     recover_tick: int, floor: float = MIN_SPEED,
                     base=None) -> SpeedSchedule:
    """``machine`` all-but-stops at ``fail_tick`` and comes back at
    ``recover_tick`` — the scenario that forces LP re-homing and then
    tests whether the partitioner thrashes everything straight back."""
    return slowdown(num_machines, machine, fail_tick,
                    factor=floor, recover_tick=recover_tick, base=base)


def pad_segments(schedule: SpeedSchedule, num_segments: int) -> SpeedSchedule:
    """Extend a schedule to ``num_segments`` by repeating its last row.

    The last segment extends forever, so appending copies of it at later
    tick boundaries is semantics-preserving: ``speeds_at`` returns the
    same (K,) vector at every tick.  This is how differently-shaped
    schedules become stackable for a batched DES run (DESIGN.md §12.4).
    """
    have = schedule.times.shape[0]
    if have > num_segments:
        raise ValueError(f"schedule already has {have} > {num_segments} "
                         "segments")
    if have == num_segments:
        return schedule
    extra = num_segments - have
    times = jnp.concatenate([
        schedule.times,
        schedule.times[-1] + jnp.arange(1, extra + 1, dtype=jnp.int32)])
    speeds = jnp.concatenate([
        schedule.speeds, jnp.tile(schedule.speeds[-1:], (extra, 1))])
    return SpeedSchedule(times=times, speeds=speeds)


def stack_schedules(schedules) -> SpeedSchedule:
    """Stack B schedules into one ``SpeedSchedule`` with ``(B, S)`` times
    and ``(B, S, K)`` speeds, padding shorter ones via :func:`pad_segments`
    — the schedule operand of a batched DES run
    (:func:`repro.des.engine.run_simulation_batch`, DESIGN.md §12.4)."""
    schedules = list(schedules)
    if not schedules:
        raise ValueError("cannot stack an empty sequence of schedules")
    ks = {s.num_machines for s in schedules}
    if len(ks) != 1:
        raise ValueError(f"schedules disagree on machine count: {sorted(ks)}")
    target = max(s.times.shape[0] for s in schedules)
    padded = [pad_segments(s, target) for s in schedules]
    return SpeedSchedule(
        times=jnp.stack([s.times for s in padded]),
        speeds=jnp.stack([s.speeds for s in padded]))


def random_churn(num_machines: int, num_segments: int, segment_ticks: int,
                 seed, low: float = 0.3, high: float = 1.0) -> SpeedSchedule:
    """Every ``segment_ticks`` ticks each machine's speed is re-drawn
    uniformly from [low, high] — sustained background churn."""
    if num_segments < 1 or segment_ticks < 1:
        raise ValueError("need >= 1 segment of >= 1 tick")
    rng = np.random.default_rng(seed)
    rows = rng.uniform(low, high,
                       size=(num_segments, num_machines)).astype(np.float32)
    times = np.arange(num_segments, dtype=np.int32) * segment_ticks
    return make_schedule(times, rows)
