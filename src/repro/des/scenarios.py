"""Machine-churn scenarios: piecewise-constant per-machine speed schedules.

The paper's cost frameworks carry per-machine speeds ``w_k`` (Eq. 1/6)
precisely because real clusters are not uniform AND not static: machines
slow down (co-tenancy, thermal throttling), fail, and recover while the
workload's hot spots move.  A :class:`SpeedSchedule` is the minimal model
of that churn — a sorted list of wall-clock tick boundaries and, per
segment, the (K,) relative machine speeds in effect (1.0 = nominal; see
DESIGN.md §11).  ``repro.des.engine`` consumes it per tick: busy-time
scales inversely with the resident machine's current speed, and each
refinement round feeds the live speeds into the partition game.

Builders are host-side (numpy); the schedule itself is jnp arrays so
``speeds_at`` traces inside the engine's ``lax.while_loop``.

Speeds are clamped to ``MIN_SPEED`` by default — a "failed" machine is
modeled as nearly-stopped rather than stopped.  Passing ``floor=0.0``
(or using :func:`true_failure`) lifts the clamp: speed ``0`` is the
engine's "machine down" state (DESIGN.md §15.5) — the machine's LPs are
quarantined (queues frozen, no busy-time countdown) until the schedule
restores a positive speed, and the refinement layer re-homes LPs off the
dead machine via the existing game.

:func:`refine_exchange_loss` covers the *other* refinement-layer fault
class: candidate exchanges lost on the wire (a ``FaultPlan`` for the
distributed drivers, DESIGN.md §15.1), so ``dynamics_bench`` can measure
load CV through both machine death and message loss.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

Array = jax.Array

MIN_SPEED = 0.02   # floor for "failed" machines (busy-time divides by speed)


class SpeedSchedule(NamedTuple):
    """Piecewise-constant machine speeds over wall-clock ticks.

    Segment ``s`` is in effect for ticks in ``[times[s], times[s+1])``
    (the last segment extends forever).  ``times[0]`` must be 0 so every
    tick is covered.
    """
    times: Array    # (S,) int32 — ascending segment-start ticks, times[0]=0
    speeds: Array   # (S, K) float32 — relative speeds, 1.0 = nominal

    @property
    def num_machines(self) -> int:
        return self.speeds.shape[1]


def make_schedule(times, speeds, *, floor: float = MIN_SPEED
                  ) -> SpeedSchedule:
    """Validate + clamp host-side arrays into a :class:`SpeedSchedule`.

    ``floor`` is the speed clamp; the ``MIN_SPEED`` default keeps the
    pre-fault-model "failure = nearly stopped" semantics.  ``floor=0.0``
    permits exact-zero segments — the engine's "machine down" state
    (:func:`true_failure`); negative inputs clamp to the floor either
    way."""
    times = np.asarray(times, np.int32)
    speeds = np.asarray(speeds, np.float32)
    if times.ndim != 1 or speeds.ndim != 2 or times.shape[0] != speeds.shape[0]:
        raise ValueError(f"shape mismatch: times {times.shape} vs "
                         f"speeds {speeds.shape}")
    if times.shape[0] == 0 or times[0] != 0:
        raise ValueError("times must start at tick 0")
    if np.any(np.diff(times) <= 0):
        raise ValueError("times must be strictly ascending")
    speeds = np.maximum(speeds, np.float32(floor))
    return SpeedSchedule(times=jnp.asarray(times),
                         speeds=jnp.asarray(speeds))


def segment_at(schedule: SpeedSchedule, tick: Array) -> Array:
    """() i32 — index of the segment in effect at wall-clock ``tick``
    (traceable; the telemetry ``tick`` events carry it so churn phases
    are attributable in a run log, DESIGN.md §14)."""
    idx = jnp.sum((schedule.times <= tick).astype(jnp.int32)) - 1
    return jnp.clip(idx, 0, schedule.times.shape[0] - 1)


def speeds_at(schedule: SpeedSchedule, tick: Array) -> Array:
    """(K,) speeds in effect at wall-clock ``tick`` (traceable)."""
    return schedule.speeds[segment_at(schedule, tick)]


# ---------------------------------------------------------------------------
# scenario builders (host-side)
# ---------------------------------------------------------------------------

def constant(num_machines: int, speeds=None) -> SpeedSchedule:
    """One segment: fixed (possibly heterogeneous) speeds forever."""
    row = np.ones(num_machines, np.float32) if speeds is None \
        else np.asarray(speeds, np.float32)
    return make_schedule([0], row[None, :])


def slowdown(num_machines: int, machine: int, at_tick: int,
             factor: float = 0.25, recover_tick: int | None = None,
             base=None) -> SpeedSchedule:
    """``machine`` drops to ``factor`` of its base speed at ``at_tick``
    (co-tenant / throttling churn), optionally recovering later."""
    base = np.ones(num_machines, np.float32) if base is None \
        else np.asarray(base, np.float32)
    rows, times = [base], [0]
    slow = base.copy()
    slow[machine] = base[machine] * factor
    rows.append(slow)
    times.append(at_tick)
    if recover_tick is not None:
        rows.append(base)
        times.append(recover_tick)
    return make_schedule(times, np.stack(rows))


def failure_recovery(num_machines: int, machine: int, fail_tick: int,
                     recover_tick: int, floor: float = MIN_SPEED,
                     base=None) -> SpeedSchedule:
    """``machine`` all-but-stops at ``fail_tick`` and comes back at
    ``recover_tick`` — the scenario that forces LP re-homing and then
    tests whether the partitioner thrashes everything straight back."""
    return slowdown(num_machines, machine, fail_tick,
                    factor=floor, recover_tick=recover_tick, base=base)


def true_failure(num_machines: int, machine: int, fail_tick: int,
                 recover_tick: int | None = None, base=None) -> SpeedSchedule:
    """``machine`` is DOWN (speed exactly 0) from ``fail_tick`` until
    ``recover_tick`` (forever if ``None``) — the DESIGN.md §15.5 fault
    scenario.  Unlike :func:`failure_recovery`'s near-zero floor, the
    engine quarantines the machine's LPs outright: queues freeze, busy
    jobs suspend mid-countdown, and the frozen local clocks hold GVT
    back until recovery, while each refinement round re-homes LPs off
    the dead machine via the partition game."""
    base = np.ones(num_machines, np.float32) if base is None \
        else np.asarray(base, np.float32)
    down = base.copy()
    down[machine] = 0.0
    if fail_tick == 0:        # down from the first tick: no base segment
        rows, times = [down], [0]
    else:
        rows, times = [base, down], [0, fail_tick]
    if recover_tick is not None:
        rows.append(base)
        times.append(recover_tick)
    return make_schedule(times, np.stack(rows), floor=0.0)


def refine_exchange_loss(num_rounds: int, num_shards: int, seed: int = 0, *,
                         p_lost: float = 0.2, max_lost: int = 3,
                         num_machines: int = 1, num_nodes: int = 0):
    """Refinement-layer exchange-loss scenario: a seeded
    :class:`repro.distributed.faults.FaultPlan` where candidate
    exchanges are lost on the wire with probability ``p_lost`` per
    (round, shard) — each loss costs up to ``max_lost`` bounded retries
    before the round proceeds on the stale aggregate (DESIGN.md §15.2).
    Pass it as ``fault_plan=`` to any distributed driver; pair with
    :func:`true_failure` to measure load CV through both fault classes
    in ``dynamics_bench``."""
    from repro.distributed import faults
    return faults.make_fault_plan(
        num_rounds, num_shards, seed, p_lost=p_lost, max_lost=max_lost,
        num_machines=num_machines, num_nodes=num_nodes)


def pad_segments(schedule: SpeedSchedule, num_segments: int) -> SpeedSchedule:
    """Extend a schedule to ``num_segments`` by repeating its last row.

    The last segment extends forever, so appending copies of it at later
    tick boundaries is semantics-preserving: ``speeds_at`` returns the
    same (K,) vector at every tick.  This is how differently-shaped
    schedules become stackable for a batched DES run (DESIGN.md §12.4).
    """
    have = schedule.times.shape[0]
    if have > num_segments:
        raise ValueError(f"schedule already has {have} > {num_segments} "
                         "segments")
    if have == num_segments:
        return schedule
    extra = num_segments - have
    times = jnp.concatenate([
        schedule.times,
        schedule.times[-1] + jnp.arange(1, extra + 1, dtype=jnp.int32)])
    speeds = jnp.concatenate([
        schedule.speeds, jnp.tile(schedule.speeds[-1:], (extra, 1))])
    return SpeedSchedule(times=times, speeds=speeds)


def stack_schedules(schedules) -> SpeedSchedule:
    """Stack B schedules into one ``SpeedSchedule`` with ``(B, S)`` times
    and ``(B, S, K)`` speeds, padding shorter ones via :func:`pad_segments`
    — the schedule operand of a batched DES run
    (:func:`repro.des.engine.run_simulation_batch`, DESIGN.md §12.4)."""
    schedules = list(schedules)
    if not schedules:
        raise ValueError("cannot stack an empty sequence of schedules")
    ks = {s.num_machines for s in schedules}
    if len(ks) != 1:
        raise ValueError(f"schedules disagree on machine count: {sorted(ks)}")
    target = max(s.times.shape[0] for s in schedules)
    padded = [pad_segments(s, target) for s in schedules]
    return SpeedSchedule(
        times=jnp.stack([s.times for s in padded]),
        speeds=jnp.stack([s.speeds for s in padded]))


def random_churn(num_machines: int, num_segments: int, segment_ticks: int,
                 seed, low: float = 0.3, high: float = 1.0) -> SpeedSchedule:
    """Every ``segment_ticks`` ticks each machine's speed is re-drawn
    uniformly from [low, high] — sustained background churn."""
    if num_segments < 1 or segment_ticks < 1:
        raise ValueError("need >= 1 segment of >= 1 tick")
    rng = np.random.default_rng(seed)
    rows = rng.uniform(low, high,
                       size=(num_segments, num_machines)).astype(np.float32)
    times = np.arange(num_segments, dtype=np.int32) * segment_ticks
    return make_schedule(times, rows)
