from .engine import (  # noqa: F401
    NORMAL,
    ROLLBACK,
    DESConfig,
    DESState,
    des_tick,
    make_initial_state,
    run_simulation,
)
from .workload import ThreadSpec, flooded_packet_workload  # noqa: F401
