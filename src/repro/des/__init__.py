from .engine import (  # noqa: F401
    NORMAL,
    ROLLBACK,
    DESConfig,
    DESState,
    des_tick,
    make_initial_state,
    run_simulation,
)
from .scenarios import (  # noqa: F401
    SpeedSchedule,
    constant,
    failure_recovery,
    make_schedule,
    random_churn,
    slowdown,
    speeds_at,
)
from .workload import ThreadSpec, flooded_packet_workload  # noqa: F401
