from .engine import (  # noqa: F401
    NORMAL,
    ROLLBACK,
    DESConfig,
    DESState,
    des_tick,
    make_initial_state,
    run_simulation,
    run_simulation_batch,
)
from .scenarios import (  # noqa: F401
    SpeedSchedule,
    constant,
    failure_recovery,
    make_schedule,
    pad_segments,
    random_churn,
    slowdown,
    speeds_at,
    stack_schedules,
)
from .workload import ThreadSpec, flooded_packet_workload  # noqa: F401
