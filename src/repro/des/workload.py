"""Limited-scope flooded packet-flow workload with moving hot spots (§6.1).

"Packets are generated at random times by randomly chosen LPs and these
packets flood the network for a limited number of hops ... we generate
'hot spots' of traffic or a cluster of nodes that generate large amounts of
traffic over a short period of (simulation) time.  The locations of these
hot spots change regularly."

Host-side (numpy) generation: a ThreadSpec is pure data fed to
``make_initial_state``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ThreadSpec:
    src: np.ndarray    # (T,) int32 — source LP of each flood thread
    time: np.ndarray   # (T,) float32 — simulation timestamp of injection
    count: np.ndarray  # (T,) int32 — flood scope (hop budget)


def _k_hop_cluster(adj: np.ndarray, center: int, hops: int) -> np.ndarray:
    mask = np.zeros(adj.shape[0], bool)
    mask[center] = True
    nbr = adj > 0
    for _ in range(hops):
        mask = mask | (mask @ nbr)
    return np.flatnonzero(mask)


def flooded_packet_workload(adj: np.ndarray, seed, *,
                            num_threads: int = 96,
                            num_windows: int = 4,
                            window_sim_time: float = 40.0,
                            scope: int | np.ndarray = 3,
                            hotspot_hops: int = 2,
                            hotspot_fraction: float = 0.8,
                            max_per_lp: int | None = None) -> ThreadSpec:
    """Generate flood threads concentrated in per-window moving hot spots.

    Window w covers sim time [w*W, (w+1)*W); ``hotspot_fraction`` of its
    threads originate inside a random ``hotspot_hops``-hop cluster whose
    center is re-drawn every window (the paper's moving hot spot), the rest
    uniformly.  ``scope`` is the hop budget — a scalar, or (num_threads,)
    per-thread budgets in GENERATION order (thread t of the unsorted
    sequence; the returned arrays are jointly sorted by injection time, so
    ``count`` rides the same permutation as ``src``/``time``).

    ``max_per_lp`` caps same-source threads so initial seeding fits the
    event-list capacity; when the hot-spot draw cannot place a thread
    under the cap (all 32 attempts land on full LPs) it falls back to a
    uniform draw over the LPs with capacity left, and raises ValueError
    only when NO LP has room — rather than silently overflowing:
    ``make_initial_state`` scatters one seed slot per same-source thread,
    and out-of-capacity ``.at[]`` writes would be dropped silently under
    jit.
    """
    rng = np.random.default_rng(seed)
    n = adj.shape[0]
    per_window = num_threads // num_windows
    srcs, times = [], []
    per_lp = np.zeros(n, np.int64)
    cap = max_per_lp if max_per_lp is not None else max(2, num_threads)
    counts = np.broadcast_to(np.asarray(scope, np.int32),
                             (num_threads,)).copy()

    for w in range(num_windows):
        center = int(rng.integers(n))
        cluster = _k_hop_cluster(adj, center, hotspot_hops)
        count_w = per_window if w < num_windows - 1 else \
            num_threads - per_window * (num_windows - 1)
        for _ in range(count_w):
            for _attempt in range(32):
                if rng.random() < hotspot_fraction:
                    s = int(rng.choice(cluster))
                else:
                    s = int(rng.integers(n))
                if per_lp[s] < cap:
                    break
            if per_lp[s] >= cap:
                free = np.flatnonzero(per_lp < cap)
                if free.size == 0:
                    raise ValueError(
                        f"cannot place thread {len(srcs)}: all {n} LPs are "
                        f"at max_per_lp={cap}; raise max_per_lp / "
                        f"event_capacity or lower num_threads")
                s = int(rng.choice(free))
            per_lp[s] += 1
            srcs.append(s)
            times.append(w * window_sim_time + rng.random() * window_sim_time)

    order = np.argsort(np.asarray(times, np.float32), kind="stable")
    return ThreadSpec(
        src=np.asarray(srcs, np.int32)[order],
        time=np.asarray(times, np.float32)[order],
        count=counts[order],
    )
