"""Software archetype of an optimistic (Time Warp) parallel DES (paper §6 + App. B).

This is the paper's evaluation substrate, re-expressed as a vectorized JAX
program: one wall-clock tick is one fused XLA computation over all LPs
(DESIGN.md §3.1).  The model implements, faithfully to the paper's Fig. 3-6
pseudocode:

  * per-LP event lists / histories with ``event-tick`` wall-clock transfer
    delays (inter-machine > intra-machine — the rollback-risk mechanism),
  * optimistic execution: an idle LP picks its lowest-timestamp ready event
    and advances its local virtual time,
  * ``busy-time = (#LPs on my machine) x process_time(type)`` — the paper's
    machine-speed model (speed inversely proportional to resident LPs),
  * non-causal stragglers -> rollback: history entries with time > the
    straggler's timestamp are restored to the event list and re-executed,
  * anti-messages: a rolling-back LP sends a ROLLBACK event to its neighbors
    carrying the minimum invalidated child timestamp; the receiver cancels
    matching unprocessed events and cascades if it already processed them
    (classic rollback-announcement Time Warp, see DESIGN.md §3),
  * GVT = min(local times, event timestamps) and fossil collection of
    history entries older than GVT,
  * the limited-scope flooded packet-flow workload: completed events with
    hop count > 0 forward to every neighbor that has not yet seen the
    thread,
  * periodic partition refinement: every ``refine_freq`` ticks node/edge
    weights are measured from the live event lists (b_i = event-list length,
    c_ij = mutual pending-spawn counts, §6.1) and the game-theoretic
    refinement reassigns LPs to machines.

Deviations from the prose (documented in DESIGN.md §3/§8):

  * per (sender, receiver) pair at most one message per tick — multiple
    anti-messages coalesce into one announcement carrying the min cancelled
    timestamp, which is the standard Time Warp optimization;
  * the paper's Fig. 6 dedup ("if current-event not present in event list
    or history of neighbor") reads the receiver's *optimistic wall-clock*
    state, which is not causally safe: a node that optimistically received
    a thread via a long path would refuse the (simulation-time-earlier)
    short-path copy and flood with a smaller hop budget than sequential
    execution would — the one thing a Time Warp simulator must never do.
    We implement the timestamp-aware variant: ``seen_time[n, t]`` tracks
    the earliest receipt timestamp per (LP, thread); a copy is forwarded
    iff strictly earlier than the receiver's current earliest, received
    later copies are consumed as duplicates (recorded in history so
    cancellations can revive them), and ``seen_time`` is recomputed from
    the live records each tick so rollbacks restore it automatically.
    tests/test_des.py::test_flood_closure_oracle proves the result: the
    final seen-sets equal the exact k-hop closures under any placement,
    delays, stragglers and rollbacks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core import costs as game_costs
from ..core.problem import PartitionProblem
from ..core.refine import refine
from .scenarios import SpeedSchedule, segment_at, speeds_at

Array = jax.Array

NORMAL = 0
ROLLBACK = 1

_INF = jnp.float32(3.0e38)
_BIG_I = jnp.int32(0x3FFFFFFF)

# Declared asymptotic budget for the DES tick, consumed by the
# complexity analyzers (DESIGN.md §18).  The engine consumes the dense
# (N, N) topology and the router scatters over (lp, slot, dest-lp)
# windows, so the tick legitimately stages O(N^2)-shaped intermediates
# (event_capacity is a static constant, not a problem dimension).
DES_COMPLEXITY = {
    "mem": {"n": 2.0, "k": 1.0},
    "ops": {"n": 2.0, "k": 1.0},
}


@dataclasses.dataclass(frozen=True)
class DESConfig:
    num_lps: int
    num_machines: int
    num_threads: int
    event_capacity: int = 24
    history_capacity: int = 48
    proc_ticks: int = 2           # get_process_time(NORMAL) base cost
    inter_delay: int = 6          # event-tick for cross-machine transfer
    intra_delay: int = 1          # event-tick for same-machine transfer
    hop_sim_latency: float = 1.0  # simulation-time increment per hop
    max_ticks: int = 20_000
    # heterogeneous machines (DESIGN.md §11): relative per-machine speeds
    # (1.0 = nominal; busy-time divides by the resident machine's speed).
    # None = uniform.  A SpeedSchedule passed to run_simulation/des_tick
    # overrides this per tick (speed churn scenarios, des/scenarios.py).
    machine_speeds: tuple[float, ...] | None = None
    # partition refinement
    refine_freq: int = 0          # 0 = never refine
    refine_framework: str = game_costs.C_FRAMEWORK
    refine_max_turns: int = 256
    refine_mu: float = 8.0
    # "single" = the single-controller loop of core/refine.py;
    # "distributed" = the sharded O(K)-exchange runtime of
    # repro.distributed (DESIGN.md §9) — same fixed points, but the
    # repartition step itself runs as the sharded protocol.
    refine_backend: str = "single"
    refine_num_shards: int = 0    # 0 = one shard per machine
    # Both backends run the incremental aggregate-state path (DESIGN.md
    # §10) by default; for the single backend, refine_verify_every=M > 0
    # additionally cross-checks the carried aggregate against a rebuild
    # every M turns of each refinement round (drift-bounding knob for
    # long-running simulations).
    refine_incremental: bool = True
    refine_verify_every: int = 0
    # migration-aware hysteresis (DESIGN.md §11): an LP migrates only when
    # its dissatisfaction exceeds theta_i = refine_theta_scale * its live
    # state size (event-list + history occupancy — the records a migration
    # must ship).  0 = migration treated as free (today's behavior).
    refine_theta_scale: float = 0.0
    # transfer freeze: a migrated LP is frozen for
    # round(migration_freeze * state_size * inter_delay) wall ticks (the
    # state transfer it must wait for), so load traces reflect thrashing.
    # 0 = instantaneous migration (today's behavior).
    migration_freeze: float = 0.0
    # load trace (Figs 9/10)
    trace_stride: int = 50
    max_trace: int = 512


class EventLists(NamedTuple):
    time: Array     # (N, E) f32 — simulation timestamp
    thread: Array   # (N, E) i32 — flood-thread id (-1 for rollback events)
    typ: Array      # (N, E) i32 — NORMAL / ROLLBACK
    tick: Array     # (N, E) i32 — wall ticks before the event is processable
    count: Array    # (N, E) i32 — remaining hop count (NORMAL) or the
                    #              invalidated send-epoch (ROLLBACK)
    sender: Array   # (N, E) i32 — LP that sent the event (-1 = initial)
    epoch: Array    # (N, E) i32 — sender's send-epoch when the message left
    valid: Array    # (N, E) bool


class History(NamedTuple):
    time: Array     # (N, H) f32
    thread: Array   # (N, H) i32
    count: Array    # (N, H) i32
    sender: Array   # (N, H) i32
    epoch: Array    # (N, H) i32
    dup: Array      # (N, H) bool — consumed as duplicate (never processed/
                    #               forwarded); revived if the canonical copy
                    #               is cancelled
    valid: Array    # (N, H) bool


class DESState(NamedTuple):
    ev: EventLists
    hist: History
    local_time: Array   # (N,) f32
    busy: Array         # (N,) bool
    busy_tick: Array    # (N,) i32
    cur_time: Array     # (N,) f32 — event currently being processed
    cur_thread: Array   # (N,) i32
    cur_count: Array    # (N,) i32
    cur_sender: Array   # (N,) i32 — sender of the event being processed
    machine: Array      # (N,) i32
    seen_time: Array    # (N, T) f32 — earliest receipt timestamp (_INF = never)
    epoch: Array        # (N,) i32 — per-LP send epoch; bumped on every
                        #            rollback so anti-messages cancel ONLY
                        #            messages sent before the rollback
                        #            (re-sends carry the new epoch and are
                        #            immune — the 1:1 anti-message pairing
                        #            of classic Time Warp, aggregated)
    tick: Array         # ()  i32 — wall clock
    gvt: Array          # ()  f32 — global virtual time
    done: Array         # ()  bool
    # statistics
    rollbacks: Array    # () i32 — rollback occurrences (straggler + anti-msg)
    processed: Array    # () i32 — events processed to completion
    dropped: Array      # () i32 — proposals dropped for capacity (should be 0)
    hist_evict: Array   # () i32 — history evictions (should be 0)
    refines: Array      # () i32 — refinement rounds executed
    moves: Array        # () i32 — LP migrations applied by refinement
    # load trace (Figs 9/10): mean event-list length per machine over time
    trace: Array        # (max_trace, K) f32
    # speed-normalized machine backlog Q_k / w_k at the same trace ticks:
    # drain rate is proportional to machine speed, so equal Q_k/w_k means
    # equal time-to-drain — the L_k/w_k balance of Eq. 8 (DESIGN.md §11)
    trace_wload: Array  # (max_trace, K) f32
    trace_ptr: Array    # () i32

    @property
    def seen(self) -> Array:
        """(N, T) bool — which LPs have (validly) received each thread."""
        return self.seen_time < _INF / 2


def make_initial_state(cfg: DESConfig, machine0: Array,
                       thread_src: Array, thread_time: Array,
                       thread_count: Array) -> DESState:
    """Seed each flood thread into its source LP's event list at t=0."""
    N, E, H, T = (cfg.num_lps, cfg.event_capacity, cfg.history_capacity,
                  cfg.num_threads)
    ev = EventLists(
        time=jnp.full((N, E), _INF),
        thread=jnp.full((N, E), -1, jnp.int32),
        typ=jnp.zeros((N, E), jnp.int32),
        tick=jnp.zeros((N, E), jnp.int32),
        count=jnp.zeros((N, E), jnp.int32),
        sender=jnp.full((N, E), -1, jnp.int32),
        epoch=jnp.zeros((N, E), jnp.int32),
        valid=jnp.zeros((N, E), bool),
    )
    # place thread t into slot = running count of earlier threads at the
    # same source (host-side guarantees counts fit in E)
    thread_src = jnp.asarray(thread_src, jnp.int32)
    same_src_before = jnp.sum(
        (thread_src[None, :] == thread_src[:, None])
        & (jnp.arange(T)[None, :] < jnp.arange(T)[:, None]), axis=1)
    slots = same_src_before.astype(jnp.int32)
    ev = ev._replace(
        time=ev.time.at[thread_src, slots].set(jnp.asarray(thread_time, jnp.float32)),
        thread=ev.thread.at[thread_src, slots].set(jnp.arange(T, dtype=jnp.int32)),
        count=ev.count.at[thread_src, slots].set(jnp.asarray(thread_count, jnp.int32)),
        valid=ev.valid.at[thread_src, slots].set(True),
    )
    # seen_time starts unknown everywhere; the injected event-list records
    # themselves define the sources' receipt times (recomputed every tick).
    seen_time0 = jnp.full((N, T), _INF)
    hist = History(
        time=jnp.full((N, H), _INF),
        thread=jnp.full((N, H), -1, jnp.int32),
        count=jnp.zeros((N, H), jnp.int32),
        sender=jnp.full((N, H), -1, jnp.int32),
        epoch=jnp.zeros((N, H), jnp.int32),
        dup=jnp.zeros((N, H), bool),
        valid=jnp.zeros((N, H), bool),
    )
    K = cfg.num_machines
    return DESState(
        ev=ev, hist=hist,
        local_time=jnp.zeros((N,), jnp.float32),
        busy=jnp.zeros((N,), bool),
        busy_tick=jnp.zeros((N,), jnp.int32),
        cur_time=jnp.full((N,), _INF),
        cur_thread=jnp.full((N,), -1, jnp.int32),
        cur_count=jnp.zeros((N,), jnp.int32),
        cur_sender=jnp.full((N,), -1, jnp.int32),
        machine=jnp.asarray(machine0, jnp.int32),
        seen_time=seen_time0,
        epoch=jnp.zeros((N,), jnp.int32),
        tick=jnp.zeros((), jnp.int32),
        gvt=jnp.zeros((), jnp.float32),
        done=jnp.zeros((), bool),
        rollbacks=jnp.zeros((), jnp.int32),
        processed=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
        hist_evict=jnp.zeros((), jnp.int32),
        refines=jnp.zeros((), jnp.int32),
        moves=jnp.zeros((), jnp.int32),
        trace=jnp.zeros((cfg.max_trace, K), jnp.float32),
        trace_wload=jnp.zeros((cfg.max_trace, K), jnp.float32),
        trace_ptr=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# One wall-clock tick
# ---------------------------------------------------------------------------

def _base_speeds(cfg: DESConfig) -> Array:
    """(K,) static relative machine speeds from the config (1.0 = nominal)."""
    if cfg.machine_speeds is None:
        return jnp.ones((cfg.num_machines,), jnp.float32)
    if len(cfg.machine_speeds) != cfg.num_machines:
        raise ValueError(
            f"machine_speeds has {len(cfg.machine_speeds)} entries for "
            f"{cfg.num_machines} machines")
    return jnp.asarray(cfg.machine_speeds, jnp.float32)


def _live_state_size(state: DESState) -> Array:
    """(N,) per-LP live state size: event-list + history occupancy — the
    records a migration must ship (sizes theta and the transfer freeze)."""
    return (jnp.sum(state.ev.valid, axis=1)
            + jnp.sum(state.hist.valid, axis=1)).astype(jnp.float32)


def _select_events(ev: EventLists, idle: Array):
    """Per LP: pick the lowest-timestamp ready event (tick == 0); among ties
    prefer ROLLBACK events, then the lowest slot.  Returns (has, slot)."""
    ready = ev.valid & (ev.tick == 0)
    ts = jnp.where(ready, ev.time, _INF)
    mints = jnp.min(ts, axis=1)
    has = idle & (mints < _INF)
    E = ev.time.shape[1]
    cand = ready & (ts <= mints[:, None])
    score = jnp.where(cand,
                      (ev.typ == ROLLBACK).astype(jnp.int32) * (2 * E)
                      + (E - 1 - jnp.arange(E)[None, :]),
                      -1)
    slot = jnp.argmax(score, axis=1).astype(jnp.int32)
    return has, slot


def des_tick(cfg: DESConfig, adj: Array, state: DESState,
             speed_schedule: SpeedSchedule | None = None,
             emit_tick=None, emit_refine=None) -> DESState:
    """Advance the simulator by one wall-clock tick.

    ``speed_schedule`` (optional) supplies the per-machine speeds in
    effect this tick (speed-churn scenarios, :mod:`repro.des.scenarios`);
    otherwise ``cfg.machine_speeds`` applies throughout.

    ``emit_tick`` / ``emit_refine`` (DESIGN.md §14.3) are host callback
    targets for telemetry: at ``trace_stride`` cadence a cond-gated
    ``jax.debug.callback`` streams one tick row (GVT, counters, backlog
    CV, schedule segment, frozen-LP count), and each executed refinement
    round streams one refine row.  ``None`` (default) traces the exact
    pre-telemetry program — no callbacks in the jaxpr.
    """
    N, E, H = cfg.num_lps, cfg.event_capacity, cfg.history_capacity
    K = cfg.num_machines
    ev, hist = state.ev, state.hist
    nbr = adj > 0
    rows = jnp.arange(N)
    speeds = _base_speeds(cfg) if speed_schedule is None \
        else speeds_at(speed_schedule, state.tick)
    # speed <= 0 means "machine down" (DESIGN.md §15.5): its LPs are
    # quarantined for the segment — no event selection, no busy-time
    # countdown, no completions — so the queue freezes in place instead of
    # dividing by zero (the old code fed speed=0 straight into the busy
    # ceil, producing inf -> int32).  Frozen local clocks hold GVT back,
    # so no surviving LP can fossil-collect past the down machine's
    # unprocessed events; when the schedule restores the speed the queue
    # drains normally.  All-positive speeds leave every gate constant-
    # false and the tick bitwise-identical.
    lp_down = speeds[state.machine] <= 0.0

    # ---- P0: transfer-delay countdown (only events already in lists) -------
    ev = ev._replace(tick=jnp.maximum(ev.tick - (ev.valid & (ev.tick > 0)), 0))

    # ---- P0b: recompute seen_time from the live records --------------------
    # seen_time[n, t] = earliest receipt timestamp of thread t at LP n,
    # derived from (a) pending event-list copies, (b) history (processed or
    # duplicate) copies, (c) the permanent part: receipts older than GVT can
    # never be rolled back (their records fossil-collect at exactly the same
    # threshold).  Recomputing instead of patching makes cancellation /
    # restore automatically consistent (DESIGN.md deviation note).
    Tn = cfg.num_threads
    tids = jnp.arange(Tn, dtype=jnp.int32)
    ev_match = ev.valid[:, :, None] & (ev.thread[:, :, None] == tids)
    ev_seen = jnp.min(jnp.where(ev_match, ev.time[:, :, None], _INF), axis=1)
    hist_match = hist.valid[:, :, None] & (hist.thread[:, :, None] == tids)
    hist_seen = jnp.min(jnp.where(hist_match, hist.time[:, :, None], _INF),
                        axis=1)
    perm = jnp.where(state.seen_time < state.gvt, state.seen_time, _INF)
    seen_time = jnp.minimum(jnp.minimum(ev_seen, hist_seen), perm)

    # ---- P1: busy LPs advance; completions forward the flood ---------------
    # (down machines' LPs neither count down nor complete — frozen mid-job)
    was_busy = state.busy
    busy_tick = jnp.where(was_busy & ~lp_down, state.busy_tick - 1,
                          state.busy_tick)
    completed = was_busy & ~lp_down & (busy_tick <= 0)
    still_busy = was_busy & ~completed
    # transfer-freeze completions (cur_thread == -1, no event in flight —
    # see _refine_partition) release the LP without counting as processed
    processed = state.processed + jnp.sum(
        (completed & (state.cur_thread >= 0)).astype(jnp.int32))

    fwd_send = completed & (state.cur_count > 0)
    fwd_thread = state.cur_thread
    fwd_time = state.cur_time + cfg.hop_sim_latency
    fwd_count = state.cur_count - 1

    # ---- P2: idle LPs select and locally handle one event ------------------
    # (down machines' LPs are quarantined: they select nothing this tick)
    idle = ~was_busy & ~lp_down
    has, slot = _select_events(ev, idle)
    sel_time = ev.time[rows, slot]
    sel_thread = ev.thread[rows, slot]
    sel_typ = ev.typ[rows, slot]
    sel_count = ev.count[rows, slot]
    sel_sender = ev.sender[rows, slot]

    # duplicate: a strictly earlier copy of this thread is already known —
    # consume without processing (sequential semantics discard duplicates).
    # Recorded in history below so a cancellation of the earlier copy can
    # restore and re-canonicalize this one.
    sel_seen = seen_time[rows, jnp.clip(sel_thread, 0)]
    dup = has & (sel_typ == NORMAL) & (sel_time > sel_seen + 1e-6)

    is_rb = has & (sel_typ == ROLLBACK)
    normal = has & (sel_typ == NORMAL) & ~dup \
        & (sel_time >= state.local_time)
    straggler = has & (sel_typ == NORMAL) & ~dup \
        & (sel_time < state.local_time)

    # consume the selected slot
    ev_valid = ev.valid.at[rows, slot].set(
        jnp.where(has, False, ev.valid[rows, slot]))
    ev = ev._replace(valid=ev_valid)

    # -- rollback-event handling (anti-message with threshold sel_time) -----
    # A ROLLBACK event carries the sender's invalidated send-epoch in its
    # ``count`` field: only messages sent at-or-before that epoch cancel.
    # Messages the sender re-emits AFTER rolling back carry a later epoch
    # and must survive (classic Time Warp 1:1 message/anti-message pairing,
    # aggregated per (sender, epoch, time-threshold)).
    rb_epoch = sel_count
    # cancel unprocessed events from that sender at/after the threshold
    cancel_ev = (is_rb[:, None] & ev.valid
                 & (ev.sender == sel_sender[:, None])
                 & (ev.typ == NORMAL)
                 & (ev.epoch <= rb_epoch[:, None])
                 & (ev.time >= sel_time[:, None] - 1e-6))
    # cascaded rollback: processed events from that sender at/after threshold
    cancel_hist = (is_rb[:, None] & hist.valid
                   & (hist.sender == sel_sender[:, None])
                   & (hist.epoch <= rb_epoch[:, None])
                   & (hist.time >= sel_time[:, None] - 1e-6))
    any_casc = jnp.any(cancel_hist, axis=1)
    t_inv = jnp.min(jnp.where(cancel_hist, hist.time, _INF), axis=1)

    # restore masks: straggler restores history strictly after its timestamp;
    # cascaded rollback restores history at/after the first invalidated time
    # (minus the cancelled entries themselves, which are deleted).
    restore = jnp.where(
        straggler[:, None], hist.valid & (hist.time > sel_time[:, None]),
        jnp.where((is_rb & any_casc)[:, None],
                  hist.valid & (hist.time >= t_inv[:, None]) & ~cancel_hist,
                  False))

    rolled_back = straggler | (is_rb & any_casc)
    rollbacks = state.rollbacks + jnp.sum(rolled_back.astype(jnp.int32))

    # duplicate revival: if a cancellation removed copies of thread t at this
    # LP, any surviving history entry consumed as a DUPLICATE of that thread
    # becomes a candidate canonical again — push it back to the event list.
    Tn_ = cfg.num_threads
    tids_ = jnp.arange(Tn_, dtype=jnp.int32)
    cancelled_threads = (
        jnp.any(cancel_ev[:, :, None]
                & (ev.thread[:, :, None] == tids_), axis=1)
        | jnp.any(cancel_hist[:, :, None]
                  & (hist.thread[:, :, None] == tids_), axis=1))  # (N, T)
    revive = (hist.valid & hist.dup & (hist.thread >= 0) & ~cancel_hist
              & jnp.take_along_axis(
                  cancelled_threads, jnp.clip(hist.thread, 0), axis=1))
    restore = restore | revive

    # announcements: min invalidated *child* timestamp per rolling-back LP.
    # children were forwarded only for PROCESSED entries with hop count > 0
    # (duplicate entries never forwarded — excluding them keeps the cancel
    # threshold tight so valid earlier sends are not over-cancelled).
    inval = (restore | cancel_hist) & (hist.count > 0) & ~hist.dup
    ann_time = jnp.min(jnp.where(inval, hist.time, _INF), axis=1) \
        + cfg.hop_sim_latency
    ann_send = rolled_back & jnp.any(inval, axis=1)
    # the announcement invalidates everything this LP sent up to its CURRENT
    # epoch; the rollback itself then opens a new epoch for the re-sends
    ann_epoch = state.epoch
    new_epoch = state.epoch + rolled_back.astype(jnp.int32)

    # apply cancellations / deletions (seen_time recomputes next tick, so
    # cancelled copies automatically stop counting as received)
    ev = ev._replace(valid=ev.valid & ~cancel_ev)
    hist = hist._replace(valid=hist.valid & ~cancel_hist & ~restore)

    # -- start processing (normal + straggler) -------------------------------
    # busy-time = (#resident LPs x process_time) / machine speed: the
    # paper's density model scaled by the machine's current relative speed
    # (heterogeneity + churn, DESIGN.md §11; speed 1.0 is bit-for-bit the
    # original integer cost)
    starts = normal | straggler
    nlps = jnp.zeros((K,), jnp.int32).at[state.machine].add(1)
    # a down machine's LPs never start (idle excludes them), so the guard
    # value 1.0 is never consumed — it only keeps 0-speed out of the
    # divide (inf cast to int32 is implementation-defined)
    live_speed = jnp.where(speeds[state.machine] > 0.0,
                           speeds[state.machine], 1.0)
    busy_cost = jnp.maximum(jnp.ceil(
        (nlps[state.machine] * cfg.proc_ticks).astype(jnp.float32)
        / live_speed).astype(jnp.int32), 1)
    busy = still_busy | starts
    busy_tick = jnp.where(starts, busy_cost, busy_tick)
    cur_time = jnp.where(starts, sel_time, state.cur_time)
    cur_thread = jnp.where(starts, sel_thread, state.cur_thread)
    cur_count = jnp.where(starts, sel_count, state.cur_count)
    cur_sender = jnp.where(starts, sel_sender, state.cur_sender)
    local_time = jnp.where(starts, sel_time, state.local_time)
    local_time = jnp.where(is_rb & any_casc,
                           jnp.minimum(local_time, t_inv), local_time)

    # push started + duplicate events into history (first free slot; evict
    # oldest if full).  Duplicates are retained so that cancellation of the
    # canonical copy restores them as the new canonical.
    free_h = ~hist.valid
    has_free = jnp.any(free_h, axis=1)
    first_free = jnp.argmax(free_h, axis=1)
    oldest = jnp.argmin(jnp.where(hist.valid, hist.time, _INF), axis=1)
    hslot = jnp.where(has_free, first_free, oldest).astype(jnp.int32)
    put = starts | dup
    hist_evict = state.hist_evict + jnp.sum(
        (put & ~has_free).astype(jnp.int32))
    sel_epoch = ev.epoch[rows, slot]
    hist = History(
        time=hist.time.at[rows, hslot].set(
            jnp.where(put, sel_time, hist.time[rows, hslot])),
        thread=hist.thread.at[rows, hslot].set(
            jnp.where(put, sel_thread, hist.thread[rows, hslot])),
        count=hist.count.at[rows, hslot].set(
            jnp.where(put, sel_count, hist.count[rows, hslot])),
        sender=hist.sender.at[rows, hslot].set(
            jnp.where(put, sel_sender, hist.sender[rows, hslot])),
        epoch=hist.epoch.at[rows, hslot].set(
            jnp.where(put, sel_epoch, hist.epoch[rows, hslot])),
        dup=hist.dup.at[rows, hslot].set(
            jnp.where(put, dup, hist.dup[rows, hslot])),
        valid=hist.valid.at[rows, hslot].set(
            jnp.where(put, True, hist.valid[rows, hslot])),
    )

    # ---- P3: proposals (forwards + announcements + self-restores) ----------
    same_machine = state.machine[:, None] == state.machine[None, :]
    link_tick = jnp.where(same_machine, cfg.intra_delay, cfg.inter_delay
                          ).astype(jnp.int32)

    # forwards: ALWAYS re-forward except where suppression is provably safe.
    # Optimistic reads of the receiver's state (the paper's Fig. 6 check)
    # lose messages under rollback races, so the only two safe gates are:
    #   (a) echo suppression — never send back along the edge the copy
    #       arrived on (the parent's receipt is a causal ancestor of this
    #       send, so if this send is valid the parent has the thread);
    #   (b) permanent receipt — the receiver's earliest receipt is older
    #       than GVT, hence can never be rolled back.
    # Everything else is delivered and consumed as a duplicate at the
    # receiver (recorded in history, revivable on cancellation).
    s_grid = jnp.arange(N, dtype=jnp.int32)[:, None]
    fwd_pair = fwd_send[:, None] & nbr                       # (S, R)
    fwd_pair = fwd_pair & (jnp.arange(N)[None, :] != cur_sender[:, None])
    perm_seen = jnp.where(seen_time < state.gvt, seen_time, _INF)
    recv_perm = perm_seen.T[fwd_thread.clip(0)]              # (S, R)
    fwd_pair = fwd_pair & ~(recv_perm <= fwd_time[:, None] + 1e-6)

    ann_pair = ann_send[:, None] & nbr                       # (S, R)

    # Coalesce announcements: if the receiver already holds a ROLLBACK event
    # from the same sender *and the same epoch*, lower its threshold in
    # place instead of queueing a second one (only the minimum cancel-time
    # matters within an epoch; across epochs the events must stay distinct
    # or re-sends would be over-cancelled).
    sender_ids = jnp.arange(N, dtype=jnp.int32)
    rb_match = (ev.valid & (ev.typ == ROLLBACK))[:, :, None] \
        & (ev.sender[:, :, None] == sender_ids[None, None, :]) \
        & (ev.count[:, :, None] == ann_epoch[None, None, :])     # (R, E, S)
    has_rb = jnp.any(rb_match, axis=1)                           # (R, S)
    slot_rb = jnp.argmax(rb_match, axis=1).astype(jnp.int32)     # (R, S)
    coalesce = ann_pair.T & has_rb                               # (R, S)
    upd = jnp.where(coalesce, ann_time[None, :], _INF)
    r_idx = jnp.broadcast_to(jnp.arange(N)[:, None], (N, N))
    ev = ev._replace(time=ev.time.at[r_idx, slot_rb].min(upd))
    ann_pair = ann_pair & ~coalesce.T

    P = N + H
    prop_valid = jnp.zeros((P, N), bool)
    prop_valid = prop_valid.at[:N].set(fwd_pair | ann_pair)
    prop_valid = prop_valid.at[N:].set(restore.T)

    def sender_field(fwd_f, ann_f):
        return jnp.where(fwd_pair, fwd_f[:, None],
                         jnp.where(ann_pair, ann_f[:, None], 0))

    prop_time = jnp.concatenate([
        jnp.where(fwd_pair, fwd_time[:, None],
                  jnp.where(ann_pair, ann_time[:, None], _INF)),
        jnp.where(restore.T, state.hist.time.T, _INF),
    ], axis=0)
    prop_thread = jnp.concatenate([
        sender_field(fwd_thread, jnp.full((N,), -1, jnp.int32)),
        jnp.where(restore.T, state.hist.thread.T, -1),
    ], axis=0).astype(jnp.int32)
    prop_typ = jnp.concatenate([
        jnp.where(ann_pair, ROLLBACK, NORMAL).astype(jnp.int32),
        jnp.zeros((H, N), jnp.int32),
    ], axis=0)
    prop_count = jnp.concatenate([
        sender_field(fwd_count, ann_epoch),          # RB carries its epoch
        jnp.where(restore.T, state.hist.count.T, 0),
    ], axis=0).astype(jnp.int32)
    prop_tick = jnp.concatenate([
        jnp.where(fwd_pair | ann_pair, link_tick, 0),
        jnp.zeros((H, N), jnp.int32),
    ], axis=0).astype(jnp.int32)
    prop_sender = jnp.concatenate([
        jnp.where(fwd_pair | ann_pair, s_grid, -1),
        jnp.where(restore.T, state.hist.sender.T, -1),
    ], axis=0).astype(jnp.int32)
    # forwards are stamped with the sender's POST-rollback epoch (a sender
    # never both completes a forward and rolls back in the same tick, so
    # for actual forwarders new_epoch == old epoch); restores keep the
    # original message's epoch so later anti-messages still match them.
    prop_epoch = jnp.concatenate([
        jnp.where(fwd_pair | ann_pair, new_epoch[:, None], 0),
        jnp.where(restore.T, state.hist.epoch.T, 0),
    ], axis=0).astype(jnp.int32)

    # ---- P4: capacity-ranked insertion -------------------------------------
    free = ~ev.valid                                          # (N, E)
    free_count = jnp.sum(free, axis=1)
    order_key = jnp.where(free, jnp.arange(E)[None, :],
                          E + jnp.arange(E)[None, :])
    free_pos = jnp.argsort(order_key, axis=1).astype(jnp.int32)  # (N, E)
    prop_rank = jnp.cumsum(prop_valid.astype(jnp.int32), axis=0) - 1  # (P, N)
    accept = prop_valid & (prop_rank < free_count[None, :]) & (prop_rank < E)
    dropped = state.dropped + jnp.sum((prop_valid & ~accept).astype(jnp.int32))

    r_grid = jnp.broadcast_to(jnp.arange(N)[None, :], (P, N))
    slot_idx = free_pos[r_grid, jnp.clip(prop_rank, 0, E - 1)]   # (P, N)
    flat = jnp.where(accept, r_grid * E + slot_idx, N * E)       # dummy last

    def scatter(field_2d, updates, fill):
        padded = jnp.concatenate(
            [field_2d.reshape(-1), jnp.array([fill], field_2d.dtype)])
        padded = padded.at[flat.reshape(-1)].set(
            jnp.where(accept, updates, fill).reshape(-1).astype(field_2d.dtype))
        return padded[:-1].reshape(N, E)

    # non-accepted proposals all write to the dummy slot N*E (unique target),
    # accepted ones write to unique (receiver, slot) pairs by construction.
    ev = EventLists(
        time=scatter(ev.time, prop_time, 0.0),
        thread=scatter(ev.thread, prop_thread, 0),
        typ=scatter(ev.typ, prop_typ, 0),
        tick=scatter(ev.tick, prop_tick, 0),
        count=scatter(ev.count, prop_count, 0),
        sender=scatter(ev.sender, prop_sender, 0),
        epoch=scatter(ev.epoch, prop_epoch, 0),
        valid=scatter(ev.valid, jnp.ones((P, N), bool), False),
    )

    # accepted forwards enter the receiver's event list, so next tick's
    # seen_time recomputation picks them up automatically.

    # ---- P5: GVT, fossil collection, termination, trace ---------------------
    ev_min = jnp.min(jnp.where(ev.valid, ev.time, _INF))
    busy_min = jnp.min(jnp.where(busy, cur_time, _INF))
    lt_min = jnp.min(local_time)
    gvt = jnp.minimum(jnp.minimum(ev_min, busy_min), lt_min)
    hist = hist._replace(valid=hist.valid & (hist.time >= gvt))
    done = (~jnp.any(ev.valid)) & (~jnp.any(busy))

    tick = state.tick + 1
    lens = jnp.sum(ev.valid, axis=1).astype(jnp.float32)
    nlps_f = jnp.maximum(
        jnp.zeros((K,), jnp.float32).at[state.machine].add(1.0), 1.0)
    total_len = jnp.zeros((K,), jnp.float32).at[state.machine].add(lens)
    mean_len = total_len / nlps_f
    wload = total_len / jnp.maximum(speeds, 1e-6)
    # the trace stops (rather than overwriting its last row) once full:
    # trace_ptr is clamped to max_trace so downstream slicing with it is
    # always in bounds
    do_trace = (tick % cfg.trace_stride == 0) \
        & (state.trace_ptr < cfg.max_trace)
    ptr = jnp.clip(state.trace_ptr, 0, cfg.max_trace - 1)
    trace = jnp.where(do_trace,
                      state.trace.at[ptr].set(mean_len), state.trace)
    trace_wload = jnp.where(do_trace,
                            state.trace_wload.at[ptr].set(wload),
                            state.trace_wload)
    trace_ptr = jnp.minimum(state.trace_ptr + do_trace.astype(jnp.int32),
                            cfg.max_trace)

    new_state = state._replace(
        ev=ev, hist=hist, local_time=local_time, busy=busy,
        busy_tick=busy_tick, cur_time=cur_time, cur_thread=cur_thread,
        cur_count=cur_count, cur_sender=cur_sender, seen_time=seen_time,
        epoch=new_epoch, tick=tick, gvt=gvt, done=done,
        rollbacks=rollbacks, processed=processed, dropped=dropped,
        hist_evict=hist_evict, trace=trace, trace_wload=trace_wload,
        trace_ptr=trace_ptr)

    # ---- P6: periodic partition refinement (the paper's contribution) ------
    if cfg.refine_freq > 0:
        new_state = jax.lax.cond(
            (tick % cfg.refine_freq == 0) & ~done,
            lambda s: _refine_partition(cfg, adj, s, speeds,
                                        emit_refine=emit_refine),
            lambda s: s, new_state)

    # ---- P7: telemetry (DESIGN.md §14.3) -----------------------------------
    if emit_tick is not None:
        segment = (jnp.zeros((), jnp.int32) if speed_schedule is None
                   else segment_at(speed_schedule, state.tick))
        frozen = jnp.sum((new_state.busy
                          & (new_state.cur_thread == -1)).astype(jnp.int32))
        wmean = jnp.mean(wload)
        wload_cv = jnp.std(wload) / jnp.maximum(wmean, 1e-12)
        row = (tick, gvt, new_state.processed, new_state.rollbacks,
               new_state.refines, new_state.moves, jnp.mean(mean_len),
               wload_cv, segment, frozen)
        jax.lax.cond(tick % cfg.trace_stride == 0,
                     lambda: jax.debug.callback(emit_tick, *row),
                     lambda: None)
    return new_state


def _refine_partition(cfg: DESConfig, adj: Array, state: DESState,
                      speeds: Array, emit_refine=None) -> DESState:
    """Measure node/edge weights from live event lists and refine (§6.1).

    ``speeds`` is the (K,) vector of LIVE relative machine speeds this
    tick — normalized into the ``w_k`` of the cost frameworks (Eq. 1/6),
    so refinement optimizes the game the machines are actually playing.
    With ``refine_theta_scale > 0`` each LP's hysteresis threshold is
    sized by its live state (event-list + history records a migration
    must ship), and with ``migration_freeze > 0`` migrated LPs pay the
    transfer as a busy freeze (DESIGN.md §11).
    """
    K = cfg.num_machines
    b = jnp.sum(state.ev.valid, axis=1).astype(jnp.float32)
    spawn = jnp.sum(state.ev.valid & (state.ev.count > 0),
                    axis=1).astype(jnp.float32)
    c = (adj > 0).astype(jnp.float32) * (spawn[:, None] + spawn[None, :])
    live = jnp.maximum(speeds.astype(jnp.float32), 1e-6)
    prob = PartitionProblem(
        adjacency=c, node_weights=b,
        speeds=live / jnp.sum(live),
        mu=jnp.asarray(cfg.refine_mu, jnp.float32))
    state_size = _live_state_size(state)
    theta = cfg.refine_theta_scale * state_size \
        if cfg.refine_theta_scale > 0 else None
    if cfg.refine_backend == "distributed":
        from ..distributed.runtime import refine_distributed
        res = refine_distributed(prob, state.machine, cfg.refine_framework,
                                 num_shards=cfg.refine_num_shards or K,
                                 max_turns=cfg.refine_max_turns,
                                 incremental=cfg.refine_incremental,
                                 theta=theta)
    elif cfg.refine_backend == "single":
        res = refine(prob, state.machine, cfg.refine_framework,
                     max_turns=cfg.refine_max_turns,
                     incremental=cfg.refine_incremental,
                     verify_every=cfg.refine_verify_every,
                     theta=theta)
    else:
        raise ValueError(f"unknown refine_backend {cfg.refine_backend!r}")
    moved_mask = res.assignment != state.machine
    new_state = state._replace(
        machine=res.assignment,
        refines=state.refines + 1,
        moves=state.moves + jnp.sum(moved_mask.astype(jnp.int32)))
    frozen_count = jnp.zeros((), jnp.int32)
    if cfg.migration_freeze > 0:
        # the state transfer freezes the migrated LP for ticks proportional
        # to (records shipped) x (inter-machine delay); an LP mid-event
        # simply finishes that much later, an idle LP becomes busy with a
        # no-op marker (cur_thread = -1: no forward, not counted processed)
        freeze = jnp.round(cfg.migration_freeze * state_size
                           * cfg.inter_delay).astype(jnp.int32)
        frozen = moved_mask & (freeze > 0)
        newly_busy = frozen & ~state.busy
        busy_tick = jnp.where(
            frozen & state.busy, state.busy_tick + freeze,
            jnp.where(newly_busy, freeze, state.busy_tick))
        new_state = new_state._replace(
            busy=state.busy | frozen,
            busy_tick=busy_tick,
            cur_time=jnp.where(newly_busy, state.local_time, state.cur_time),
            cur_thread=jnp.where(newly_busy, -1, state.cur_thread),
            cur_count=jnp.where(newly_busy, 0, state.cur_count),
            cur_sender=jnp.where(newly_busy, -1, state.cur_sender),
        )
        frozen_count = jnp.sum(frozen.astype(jnp.int32))
    if emit_refine is not None:
        # fires only when the refinement cond branch actually executes
        jax.debug.callback(emit_refine, state.tick,
                           jnp.sum(moved_mask.astype(jnp.int32)),
                           frozen_count)
    return new_state


@partial(jax.jit, static_argnames=("cfg", "emit_tick", "emit_refine"))
def _run_simulation(cfg: DESConfig, adj: Array, state: DESState,
                    speed_schedule: SpeedSchedule | None = None,
                    emit_tick=None, emit_refine=None) -> DESState:
    def cond(s):
        return (~s.done) & (s.tick < cfg.max_ticks)

    def body(s):
        return des_tick(cfg, adj, s, speed_schedule,
                        emit_tick=emit_tick, emit_refine=emit_refine)

    return jax.lax.while_loop(cond, body, state)


def run_simulation(cfg: DESConfig, adj: Array, state: DESState,
                   speed_schedule: SpeedSchedule | None = None,
                   recorder=None) -> DESState:
    """Run ticks until all event lists drain (or max_ticks).

    ``speed_schedule`` drives per-tick machine-speed churn (slowdown /
    failure / recovery scenarios, :mod:`repro.des.scenarios`); ``None``
    keeps ``cfg.machine_speeds`` (or uniform) throughout.

    ``recorder`` (a :class:`repro.obs.Recorder`, DESIGN.md §14) opts
    into telemetry: one ``tick`` event per ``trace_stride`` ticks
    (GVT, cumulative counters, backlog CV, schedule segment, frozen
    LPs), one ``des_refine`` event per executed refinement round, and a
    closing ``run_end``.  ``recorder=None`` (default) dispatches to the
    identical jitted program — same cache entry, zero callbacks.
    """
    if recorder is None:
        return _run_simulation(cfg, adj, state, speed_schedule)
    run = recorder.new_run(
        "des", n=cfg.num_lps, k=cfg.num_machines,
        refine_freq=cfg.refine_freq, backend=cfg.refine_backend,
        trace_stride=cfg.trace_stride, theta=cfg.refine_theta_scale > 0)
    recorder.begin_rows()
    with recorder.phase("des.run_simulation", run):
        final = _run_simulation(cfg, adj, state, speed_schedule,
                                emit_tick=recorder._on_tick_row,
                                emit_refine=recorder._on_refine_row)
        jax.block_until_ready(final)
        jax.effects_barrier()
    recorder.record_des_rows(run)
    recorder.emit(
        "run_end", run, num_moves=int(final.moves),
        num_turns=int(final.tick), converged=bool(final.done),
        processed=int(final.processed), rollbacks=int(final.rollbacks),
        refines=int(final.refines), gvt=float(final.gvt))
    return final


# ---------------------------------------------------------------------------
# batched scenario fleets (DESIGN.md §12.4)
# ---------------------------------------------------------------------------

DEFAULT_BATCH_CHUNK = 256


@partial(jax.jit, static_argnames=("cfg", "chunk"))
def _run_simulation_batch(cfg: DESConfig, adjs: Array, states: DESState,
                          speed_schedules: SpeedSchedule | None = None,
                          chunk: int = DEFAULT_BATCH_CHUNK) -> DESState:
    """:func:`run_simulation` over a stack of B scenarios in one program.

    ``adjs`` is ``(B, N, N)``, ``states`` a :class:`DESState` whose
    leaves carry a leading batch axis (stack B
    :func:`make_initial_state` results), and ``speed_schedules`` is
    ``None`` or a stacked :class:`~repro.des.scenarios.SpeedSchedule`
    (``(B, S)`` times / ``(B, S, K)`` speeds — see
    :func:`repro.des.scenarios.stack_schedules`).  ``cfg`` is shared:
    the config is compile-time structure (capacities, cadences), while
    everything data-like (graph, workload, speeds) varies per element.

    A naive ``vmap(run_simulation)`` would pay the refinement branch of
    the per-tick ``lax.cond`` on EVERY tick for the whole batch (a
    batched predicate executes both branches).  Instead ticks run in
    chunks of ``cfg.refine_freq`` with refinement compiled out of the
    tick, and one vmapped refinement round applies after each chunk,
    masked per element — the same per-element cadence and cost profile
    as the looped engine (DESIGN.md §12.4).  Elements that drain (or hit
    ``max_ticks``) mid-chunk are select-masked exactly like the batched
    ``while_loop`` rule would, so every element's final state — traces
    included — is bitwise the state its own looped :func:`run_simulation`
    produces (``tests/test_sweeps.py`` + ``benchmarks/sweep_bench.py``
    pin this).  ``chunk`` only applies when ``cfg.refine_freq == 0``
    (no cadence to align with).
    """
    inner_cfg = dataclasses.replace(cfg, refine_freq=0)
    chunk = cfg.refine_freq if cfg.refine_freq > 0 \
        else max(1, min(chunk, cfg.max_ticks))
    sched_axes = None if speed_schedules is None \
        else jax.tree.map(lambda _: 0, speed_schedules)

    def masked(pred, new, old):
        return jax.tree.map(lambda a, b: jnp.where(pred, a, b), new, old)

    def tick_one(adj, s, sched):
        alive = (~s.done) & (s.tick < cfg.max_ticks)   # the while_loop cond
        return masked(alive, des_tick(inner_cfg, adj, s, sched), s)

    def refine_one(adj, s, sched, advanced):
        # des_tick refines at the END of a tick whose post-increment tick
        # hits the cadence, using that tick's live speeds — i.e. the
        # schedule row at s.tick - 1.  ``advanced`` (the element ticked
        # during this chunk) keeps an element frozen at ``max_ticks`` on
        # a cadence boundary from being re-refined every outer iteration
        # — the looped engine refines such an element exactly once.
        speeds = _base_speeds(cfg) if sched is None \
            else speeds_at(sched, s.tick - 1)
        pred = (s.tick % cfg.refine_freq == 0) & ~s.done & advanced
        return masked(pred, _refine_partition(cfg, adj, s, speeds), s)

    def chunk_body(ss):
        prev_tick = ss.tick
        def scan_body(carry, _):
            return jax.vmap(tick_one, in_axes=(0, 0, sched_axes))(
                adjs, carry, speed_schedules), None
        ss, _ = jax.lax.scan(scan_body, ss, None, length=chunk)
        if cfg.refine_freq > 0:
            ss = jax.vmap(refine_one, in_axes=(0, 0, sched_axes, 0))(
                adjs, ss, speed_schedules, ss.tick != prev_tick)
        return ss

    def cond(ss):
        return jnp.any((~ss.done) & (ss.tick < cfg.max_ticks))

    return jax.lax.while_loop(cond, chunk_body, states)


def run_simulation_batch(cfg: DESConfig, adjs: Array, states: DESState,
                         speed_schedules: SpeedSchedule | None = None,
                         chunk: int = DEFAULT_BATCH_CHUNK,
                         recorder=None) -> DESState:
    """Public batched entry point; see :func:`_run_simulation_batch`.

    ``recorder`` opts into telemetry: per-tick streaming is not
    available under the batched cond (a batched predicate executes both
    branches — exactly why refinement is hoisted out of the tick), so
    the run emits one host-side ``element`` summary per scenario after
    the fleet drains (ticks, counters, time-averaged weighted-backlog
    CV over the trace rows) plus a closing ``run_end``.
    """
    if recorder is None:
        return _run_simulation_batch(cfg, adjs, states, speed_schedules,
                                     chunk)
    from ..sweeps.metrics import time_averaged_cv
    batch = int(adjs.shape[0])
    run = recorder.new_run(
        "des_batch", n=cfg.num_lps, k=cfg.num_machines, batch=batch,
        refine_freq=cfg.refine_freq, backend=cfg.refine_backend)
    with recorder.phase("des.run_simulation_batch", run):
        final = _run_simulation_batch(cfg, adjs, states, speed_schedules,
                                      chunk)
        jax.block_until_ready(final)
    ticks = np.asarray(final.tick)
    processed = np.asarray(final.processed)
    rollbacks = np.asarray(final.rollbacks)
    refines = np.asarray(final.refines)
    moves = np.asarray(final.moves)
    done = np.asarray(final.done)
    wload = np.asarray(final.trace_wload)
    ptrs = np.asarray(final.trace_ptr)
    for i in range(batch):
        recorder.emit(
            "element", run, batch=i, ticks=int(ticks[i]),
            processed=int(processed[i]), rollbacks=int(rollbacks[i]),
            refines=int(refines[i]), moves=int(moves[i]),
            converged=bool(done[i]),
            wload_cv=time_averaged_cv(wload[i][:int(ptrs[i])]))
    recorder.emit("run_end", run, num_moves=int(moves.sum()),
                  num_turns=int(ticks.max()) if batch else 0,
                  converged=bool(done.all()))
    return final
