"""musicgen-medium [audio] — decoder-only over EnCodec tokens
(arXiv:2306.05284).  Backbone only: the EnCodec frontend is a STUB —
input_specs() supplies precomputed frame embeddings (B, S, d_model).

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048 (EnCodec codebook).
"""
from repro.models.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="musicgen-medium", family=DENSE,
    num_layers=48, d_model=1536, vocab_size=2048,
    num_heads=24, num_kv_heads=24, head_dim=64, d_ff=6144,
    input_kind="embeddings",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family=DENSE,
        num_layers=2, d_model=64, vocab_size=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        input_kind="embeddings",
        param_dtype="float32", compute_dtype="float32",
    )
