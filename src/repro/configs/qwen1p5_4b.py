"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-4B].

40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936, head_dim=128.
"""
from repro.models.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="qwen1.5-4b", family=DENSE,
    num_layers=40, d_model=2560, vocab_size=151936,
    num_heads=20, num_kv_heads=20, head_dim=128, d_ff=6912,
    qkv_bias=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", family=DENSE,
        num_layers=2, d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        qkv_bias=True,
        param_dtype="float32", compute_dtype="float32",
    )
