"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128 [arXiv:2405.21060].
d_inner = 2*d_model = 4096, ssm head_dim 64 -> 64 heads, conv width 4.
"""
from repro.models.config import ModelConfig, SSM

CONFIG = ModelConfig(
    name="mamba2-1.3b", family=SSM,
    num_layers=48, d_model=2048, vocab_size=50280,
    ssm_state=128, ssm_heads=64, ssm_head_dim=64, ssm_chunk=256,
    ssm_conv=4, ssm_expand=2,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family=SSM,
        num_layers=2, d_model=64, vocab_size=128,
        ssm_state=16, ssm_heads=4, ssm_head_dim=32, ssm_chunk=16,
        ssm_conv=4, ssm_expand=2, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
    )
