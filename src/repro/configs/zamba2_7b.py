"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
(arXiv:2411.15242).

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
d_inner = 7168, ssm head_dim 64 -> 112 ssm heads.  One SHARED attn+MLP
block (weights reused) applied every 6 Mamba2 layers (13 applications);
only those applications hold KV cache, so 524k-token decode stays cheap.
"""
from repro.models.config import ModelConfig, HYBRID

CONFIG = ModelConfig(
    name="zamba2-7b", family=HYBRID,
    num_layers=81, d_model=3584, vocab_size=32000,
    num_heads=32, num_kv_heads=32, head_dim=112, d_ff=14336,
    ssm_state=64, ssm_heads=112, ssm_head_dim=64, ssm_chunk=256,
    ssm_conv=4, ssm_expand=2,
    attn_period=6,
    param_dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family=HYBRID,
        num_layers=4, d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        ssm_state=16, ssm_heads=4, ssm_head_dim=32, ssm_chunk=16,
        ssm_conv=4, ssm_expand=2, attn_period=2,
        param_dtype="float32", compute_dtype="float32",
    )
