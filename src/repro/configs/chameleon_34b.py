"""chameleon-34b [vlm] — early-fusion, VQ image tokens (arXiv:2405.09818).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  Early fusion means
image patches arrive as VQ token ids inside the shared vocab; the VQ-GAN
tokenizer frontend is a STUB (inputs are token ids).
"""
from repro.models.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="chameleon-34b", family=DENSE,
    num_layers=48, d_model=8192, vocab_size=65536,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=22016,
    param_dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chameleon-smoke", family=DENSE,
        num_layers=2, d_model=64, vocab_size=256,
        num_heads=8, num_kv_heads=2, head_dim=8, d_ff=160,
        param_dtype="float32", compute_dtype="float32",
    )
