"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Every architecture is paired with all four LM shapes; ``train_*`` lowers
``train_step``, ``prefill_*`` lowers the prefill forward, and ``decode_*`` /
``long_*`` lower ``serve_step`` (one new token against a KV/SSM cache of
``seq_len``).  ``long_500k`` requires sub-quadratic attention and is skipped
for pure full-attention archs (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


def shape_is_applicable(cfg, shape: Shape) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 524288-token decode needs "
                       "sub-quadratic attention / constant-state decode "
                       "(DESIGN.md §5)")
    return True, ""


def input_specs(cfg, shape: Shape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No allocation happens — these feed jax.jit(...).lower() directly.
    """
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.input_kind == "embeddings":
            inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), f32)
        else:
            inputs = jax.ShapeDtypeStruct((B, S), i32)
        return {"inputs": inputs,
                "targets": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "prefill":
        if cfg.input_kind == "embeddings":
            return {"inputs": jax.ShapeDtypeStruct((B, S, cfg.d_model), f32)}
        return {"inputs": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a cache of S tokens
    if cfg.input_kind == "embeddings":
        return {"inputs": jax.ShapeDtypeStruct((B, 1, cfg.d_model), f32)}
    return {"inputs": jax.ShapeDtypeStruct((B, 1), i32)}
