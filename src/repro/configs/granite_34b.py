"""granite-34b [dense] — llama-arch code model, MQA (arXiv:2405.04324).

88L d_model=6144 48H (GQA kv=1 -> multi-query) d_ff=24576 vocab=49152.
MQA means the KV cache cannot shard over heads; decode shards the cache
sequence dim instead (sharding/rules.py).
"""
from repro.models.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="granite-34b", family=DENSE,
    num_layers=88, d_model=6144, vocab_size=49152,
    num_heads=48, num_kv_heads=1, head_dim=128, d_ff=24576,
    param_dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", family=DENSE,
        num_layers=2, d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=1, head_dim=16, d_ff=192,
        param_dtype="float32", compute_dtype="float32",
    )
