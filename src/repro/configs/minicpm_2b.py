"""minicpm-2b [dense] — WSD schedule, mu-P style scaling (arXiv:2404.06395).

40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.
Scaling: emb x12, residual x(1.4/sqrt(40)), logits /(d_model/256).
The WSD (warmup-stable-decay) LR schedule lives in training/optimizer.py.
"""
from repro.models.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="minicpm-2b", family=DENSE,
    num_layers=40, d_model=2304, vocab_size=122753,
    num_heads=36, num_kv_heads=36, head_dim=64, d_ff=5760,
    tie_embeddings=True,
    emb_multiplier=12.0,
    residual_multiplier=1.4 / (40 ** 0.5),
    logit_divisor=2304 / 256,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minicpm-smoke", family=DENSE,
        num_layers=2, d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        tie_embeddings=True, emb_multiplier=12.0,
        residual_multiplier=1.4 / (2 ** 0.5), logit_divisor=64 / 256,
        param_dtype="float32", compute_dtype="float32",
    )
