"""granite-moe-1b-a400m [moe] — 32 experts, top-8 routing.

24L d_model=1024 16H (GQA kv=8) expert d_ff=512 vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base].
"""
from repro.models.config import ModelConfig, MOE

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family=MOE,
    num_layers=24, d_model=1024, vocab_size=49155,
    num_heads=16, num_kv_heads=8, head_dim=64, d_ff=512,
    num_experts=32, top_k=8, moe_group_size=512, capacity_factor=1.25,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family=MOE,
        num_layers=2, d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=64,
        num_experts=4, top_k=2, moe_group_size=16, capacity_factor=1.5,
        tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
    )
