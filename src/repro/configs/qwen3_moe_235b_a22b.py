"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8 routing.

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936
[hf:Qwen/Qwen3-235B-A22B; head_dim=128 per the HF config].
"""
from repro.models.config import ModelConfig, MOE

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family=MOE,
    num_layers=94, d_model=4096, vocab_size=151936,
    num_heads=64, num_kv_heads=4, head_dim=128, d_ff=1536,
    num_experts=128, top_k=8, moe_group_size=512, capacity_factor=1.25,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family=MOE,
        num_layers=2, d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=96,
        num_experts=8, top_k=2, moe_group_size=16, capacity_factor=1.25,
        param_dtype="float32", compute_dtype="float32",
    )
