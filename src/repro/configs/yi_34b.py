"""yi-34b [dense] — llama-arch GQA (arXiv:2403.04652).

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000, head_dim=128.
"""
from repro.models.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="yi-34b", family=DENSE,
    num_layers=60, d_model=7168, vocab_size=64000,
    num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480,
    rope_theta=5_000_000.0,
    param_dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-smoke", family=DENSE,
        num_layers=2, d_model=64, vocab_size=128,
        num_heads=8, num_kv_heads=2, head_dim=8, d_ff=192,
        param_dtype="float32", compute_dtype="float32",
    )
