"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (exact published numbers, source noted in its
docstring) and ``smoke()`` (reduced same-family variant for CPU tests).
"""
from __future__ import annotations

import importlib

from .shapes import SHAPES, Shape, input_specs, shape_is_applicable  # noqa: F401

ARCH_IDS = (
    "mamba2_1p3b",
    "qwen3_moe_235b_a22b",
    "granite_moe_1b_a400m",
    "minicpm_2b",
    "yi_34b",
    "granite_34b",
    "qwen1p5_4b",
    "musicgen_medium",
    "chameleon_34b",
    "zamba2_7b",
)

# public --arch aliases (hyphenated, as assigned)
ALIASES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "minicpm-2b": "minicpm_2b",
    "yi-34b": "yi_34b",
    "granite-34b": "granite_34b",
    "qwen1.5-4b": "qwen1p5_4b",
    "musicgen-medium": "musicgen_medium",
    "chameleon-34b": "chameleon_34b",
    "zamba2-7b": "zamba2_7b",
}


# user-registered configs (register_config) take precedence over modules
_REGISTRY: dict = {}


def register_config(cfg, smoke=None) -> None:
    """Register a custom ModelConfig under ``cfg.name`` (examples, tests)."""
    _REGISTRY[cfg.name] = (cfg, smoke if smoke is not None else cfg)


def _module(arch: str):
    key = ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: "
                       f"{sorted(ALIASES) + sorted(_REGISTRY)}")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(arch: str):
    if arch in _REGISTRY:
        return _REGISTRY[arch][0]
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    if arch in _REGISTRY:
        return _REGISTRY[arch][1]
    return _module(arch).smoke()


def all_archs():
    return list(ALIASES.keys())
