"""Exact FLOP/byte accounting by walking the jaxpr.

XLA's HloCostAnalysis visits while-loop bodies ONCE, so for scan-over-layers
programs ``compiled.cost_analysis()`` under-reports FLOPs by ~num_layers x.
The jaxpr still has the static trip counts, so we count there:

  * dot_general  — 2 * batch * M * N * K exact
  * conv / scatter / gather — bytes-ish ops, counted elementwise
  * elementwise / transcendental — one (or a few) flops per output element
  * scan         — body flops x length
  * while        — body x (cap; not used in the LM paths)
  * cond         — max over branches (upper bound)
  * pjit / remat / custom_* — recurse

Also accumulates a naive bytes-touched estimate per primitive (inputs +
outputs), used only as a relative-correction signal for the fused HLO bytes
(see dryrun.py).
"""
from __future__ import annotations

import math
from functools import lru_cache

import jax
import numpy as np
from jax import core as jcore

_TRANSCENDENTAL = {"exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                   "sin", "cos", "pow", "cbrt", "log1p", "expm1"}
_CHEAP = {"add", "sub", "mul", "div", "max", "min", "neg", "abs", "and", "or",
          "xor", "not", "select_n", "clamp", "floor", "ceil", "round",
          "rem", "sign", "gt", "lt", "ge", "le", "eq", "ne", "integer_pow",
          "cumsum", "cumlogsumexp", "cummax", "cumprod"}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 0


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs = eqn.invars[0].aval
    batch = 1
    for d in lb:
        batch *= lhs.shape[d]
    contract = 1
    for d in lc:
        contract *= lhs.shape[d]
    m = _size(lhs) // max(batch * contract, 1)
    rhs = eqn.invars[1].aval
    rbatch = 1
    for d in rb:
        rbatch *= rhs.shape[d]
    rcontract = 1
    for d in rc:
        rcontract *= rhs.shape[d]
    n = _size(rhs) // max(rbatch * rcontract, 1)
    return 2 * batch * m * n * contract


def count_jaxpr(jaxpr, multiply_trips: bool = True) -> tuple[int, int]:
    """Returns (flops, naive_bytes) for a (closed or open) jaxpr.

    ``multiply_trips=False`` counts every scan body once — mirroring XLA's
    HloCostAnalysis behaviour, so the ratio of the two runs is exactly the
    loop-trip inflation factor to apply to HLO-reported quantities.
    """
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    flops = 0
    nbytes = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        out_size = sum(_size(v.aval) for v in eqn.outvars)
        out_bytes = sum(_bytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(_bytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
        if name == "dot_general":
            flops += _dot_flops(eqn)
            nbytes += in_bytes + out_bytes
        elif name == "scan":
            body_f, body_b = count_jaxpr(eqn.params["jaxpr"],
                                         multiply_trips)
            length = eqn.params["length"] if multiply_trips else 1
            flops += body_f * length
            nbytes += body_b * length
        elif name == "while":
            body_f, body_b = count_jaxpr(eqn.params["body_jaxpr"], multiply_trips)
            flops += body_f          # trip unknown; count once (documented)
            nbytes += body_b
        elif name == "cond":
            branches = eqn.params["branches"]
            sub = [count_jaxpr(b, multiply_trips) for b in branches]
            flops += max(s[0] for s in sub)
            nbytes += max(s[1] for s in sub)
        elif name in ("pjit", "closed_call", "core_call", "remat2",
                      "checkpoint", "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr"):
            inner = (eqn.params.get("jaxpr")
                     or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            if inner is not None:
                f, b = count_jaxpr(inner, multiply_trips)
                flops += f
                nbytes += b
        elif name in _TRANSCENDENTAL:
            flops += 8 * out_size    # polynomial approx cost on VPU
            nbytes += in_bytes + out_bytes
        elif name in _CHEAP:
            flops += out_size
            nbytes += in_bytes + out_bytes
        elif name in ("reduce_sum", "reduce_max", "reduce_min",
                      "reduce_prod", "argmax", "argmin", "reduce_and",
                      "reduce_or"):
            flops += sum(_size(v.aval) for v in eqn.invars
                         if hasattr(v, "aval"))
            nbytes += in_bytes + out_bytes
        elif name in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_slice", "dynamic_update_slice", "sort",
                      "top_k", "concatenate", "pad", "rev", "transpose",
                      "reshape", "broadcast_in_dim", "convert_element_type",
                      "slice", "iota", "select_and_scatter_add"):
            nbytes += in_bytes + out_bytes
        else:
            nbytes += in_bytes + out_bytes
    return flops, nbytes


def count_fn(fn, *args) -> tuple[int, int]:
    closed = jax.make_jaxpr(fn)(*args)
    return count_jaxpr(closed)


def count_fn_with_factor(fn, *args):
    """Returns (flops, naive_bytes, trip_factor_flops, trip_factor_bytes)."""
    closed = jax.make_jaxpr(fn)(*args)
    f1, b1 = count_jaxpr(closed, True)
    f0, b0 = count_jaxpr(closed, False)
    return f1, b1, (f1 / max(f0, 1)), (b1 / max(b0, 1))
