"""Post-SPMD HLO analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` reports FLOPs and HBM bytes but NOT collective traffic,
so we parse ``compiled.as_text()``: sum the (per-device) result sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, and multiply ops living inside while-loop bodies
(lax.scan over layers, microbatch loops) by the loop trip count recovered
from the loop-condition's comparison constant.
"""
from __future__ import annotations

import re
from collections import defaultdict

# whole bytes per element; sub-byte dtypes live in _DTYPE_BITS instead
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e4m3": 1,
}
# 4-bit dtypes pack two elements per byte (ceil over the whole buffer)
_DTYPE_BITS = {"s4": 4, "u4": 4, "f4e2m1fn": 4}
# shape tokens that legitimately carry no data
_ZERO_SIZE_DTYPES = frozenset({"token", "tuple", "opaque"})

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'f32[128,256]' or a tuple.

    Unknown dtype tokens raise instead of silently contributing 0 bytes
    — a new XLA dtype must be added to the tables above, or the
    collective accounting would quietly under-count.
    """
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype in _ZERO_SIZE_DTYPES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        if dtype in _DTYPE_BYTES:
            total += n * _DTYPE_BYTES[dtype]
        elif dtype in _DTYPE_BITS:
            total += (n * _DTYPE_BITS[dtype] + 7) // 8
        else:
            raise ValueError(
                f"unknown HLO dtype {dtype!r} in shape {shape_str!r}; "
                f"add its width to launch.hlo_analysis._DTYPE_BYTES / "
                f"_DTYPE_BITS")
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> list of instruction lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        # computation headers may contain NESTED parens (tuple-typed loop
        # carries): ``%region_0.2 (arg: (s32[], f32[8,8])) -> (...) {`` —
        # so take the name before the first '(' on any '{'-terminated
        # header line containing '->' (and no '=', which would mark an
        # instruction like fusion(...) { ... }).
        if stripped.endswith("{") and "->" in stripped \
                and "=" not in stripped.split("(", 1)[0]:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None and stripped:
            comps[cur].append(stripped)
    return comps


def _entry_computation(hlo: str) -> str | None:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    return m.group(1) if m else None


def collective_stats(hlo: str) -> dict:
    """Returns {'total_bytes', 'by_kind': {kind: bytes}, 'count'} with
    while-loop trip counts applied."""
    comps = _split_computations(hlo)

    # direct collective bytes per computation
    direct = {}
    counts = defaultdict(int)
    by_kind_direct = {}
    for name, lines in comps.items():
        total = 0
        kinds = defaultdict(int)
        for line in lines:
            for kind in COLLECTIVES:
                # match '= <shape> kind(' — the result shape precedes the op
                m = re.search(r"=\s+([^=]*?)\s+%?" + kind + r"(?:-start)?\(",
                              line)
                if m:
                    nbytes = _shape_bytes(m.group(1))
                    total += nbytes
                    kinds[kind] += nbytes
                    counts[kind] += 1
                    break
        direct[name] = total
        by_kind_direct[name] = kinds

    # while-loop structure: body/condition computation references
    calls = defaultdict(list)        # comp -> [(callee, trip)]
    for name, lines in comps.items():
        for line in lines:
            m = re.search(r"while\(.*?\).*?condition=%?([\w\.\-]+),\s*"
                          r"body=%?([\w\.\-]+)", line)
            if m:
                cond, body = m.group(1), m.group(2)
                trip = _trip_count(comps.get(cond, []))
                calls[name].append((body, trip))
            # fusion/call/conditional computations execute once
            for ref in re.findall(
                    r"(?:calls|to_apply|body|branch_computations)="
                    r"\{?%?([\w\.\-]+)", line):
                if ref in comps and "condition" not in line:
                    calls[name].append((ref, 1))

    def total_bytes(name, kinds_acc, mult, seen):
        if name in seen or name not in comps:
            return 0
        seen = seen | {name}
        out = direct.get(name, 0) * mult
        for kind, b in by_kind_direct.get(name, {}).items():
            kinds_acc[kind] += b * mult
        for callee, trip in calls.get(name, []):
            out += total_bytes(callee, kinds_acc, mult * trip, seen)
        return out

    entry = _entry_computation(hlo)
    kinds_acc = defaultdict(int)
    if entry is None:
        total = sum(direct.values())
        for km in by_kind_direct.values():
            for kind, b in km.items():
                kinds_acc[kind] += b
    else:
        total = total_bytes(entry, kinds_acc, 1, frozenset())
    return {"total_bytes": int(total),
            "by_kind": {k: int(v) for k, v in kinds_acc.items()},
            "count": dict(counts)}


def _trip_count(cond_lines: list[str]) -> int:
    """Recover a scan trip count from the loop condition: the comparison
    constant in 'compare(..., constant(N)), direction=LT'."""
    consts = []
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def flops_and_bytes(compiled) -> tuple[float, float]:
    """HLO FLOPs and HBM bytes from compiled.cost_analysis()."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    return flops, nbytes
