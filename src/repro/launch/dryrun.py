import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (test hook — still before any jax import; the production default is 512)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding rules are coherent (SPMD partitioning succeeds),
  * the per-device memory fits (memory_analysis),
  * and it extracts the roofline terms (cost_analysis + HLO collective
    parsing) consumed by ``benchmarks.roofline``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out benchmarks/results
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import SHAPES, input_specs, shape_is_applicable
from repro.launch import hlo_analysis, jaxpr_flops, traffic
from repro.launch.mesh import (HBM_BANDWIDTH, ICI_BANDWIDTH, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import (decode_step, init_cache, init_params, prefill)
from repro.sharding import rules
from repro.training import TrainState, init_train_state
from repro.training.train_step import TrainHyper, make_train_step


def _replicated_like(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def build_cell(arch: str, shape_name: str, mesh, *,
               strategy: str = "fsdp", microbatches: int = 1,
               cfg_overrides: dict | None = None):
    """Returns (jitted_fn, example_args, donate) for the cell."""
    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        hyper = TrainHyper(microbatches=microbatches)
        step_fn = make_train_step(cfg, hyper)
        state = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
        state_sh = rules.state_shardings(cfg, mesh, state,
                                         strategy=strategy)
        batch_sh = rules.batch_shardings(cfg, mesh, specs)
        metrics = {"loss": 0, "ce": 0, "aux_loss": 0, "grad_norm": 0, "lr": 0}
        jitted = jax.jit(step_fn,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, _replicated_like(mesh, metrics)),
                         donate_argnums=(0,))
        return jitted, step_fn, (state, specs)

    params = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    # inference has no optimizer state: shard params over 'model' only
    # (local reads, no per-step weight re-gathers) whenever the model-shard
    # fits comfortably; big MoE stacks keep the (data x model) sharding and
    # pay the per-layer gather (§Perf iteration 5)
    if strategy == "fsdp":
        tp = mesh.shape.get("model", 1)
        bytes_p = 2 if cfg.param_dtype == "bfloat16" else 4
        p_shard_gb = (cfg.param_count() + cfg.shared_block_params()) \
            * bytes_p / tp / 1e9
        strategy = "zero1" if p_shard_gb < 8.0 else "fsdp"
    params_sh = rules.param_shardings(cfg, mesh, params, strategy=strategy)

    if shape.kind == "prefill":
        def prefill_fn(p, inputs):
            return prefill(p, cfg, inputs, max_len=shape.seq_len)

        cache = jax.eval_shape(
            lambda: _abstract_prefill_cache(cfg, shape))
        cache_sh = rules.cache_shardings(cfg, mesh, cache)
        logits_sh = _logits_sharding(cfg, mesh, shape.global_batch)
        jitted = jax.jit(prefill_fn,
                         in_shardings=(params_sh,
                                       rules.batch_shardings(cfg, mesh,
                                                             {"inputs": specs["inputs"]})["inputs"]),
                         out_shardings=((logits_sh, cache_sh)))
        return jitted, prefill_fn, (params, specs["inputs"])

    # decode: one token against a cache of seq_len (cache position S-1 by
    # convention; slot S-1 receives the new token)
    def serve_fn(p, cache, inputs):
        return decode_step(p, cfg, inputs, cache)

    cache = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           cfg.cdtype()))
    cache_sh = rules.cache_shardings(cfg, mesh, cache)
    logits_sh = _logits_sharding(cfg, mesh, shape.global_batch)
    tok_sh = rules.batch_shardings(cfg, mesh, {"inputs": specs["inputs"]})["inputs"]
    jitted = jax.jit(serve_fn,
                     in_shardings=(params_sh, cache_sh, tok_sh),
                     out_shardings=(logits_sh, cache_sh),
                     donate_argnums=(1,))
    return jitted, serve_fn, (params, cache, specs["inputs"])


def _abstract_prefill_cache(cfg, shape):
    from repro.models import init_cache as _ic
    return _ic(cfg, shape.global_batch, shape.seq_len, cfg.cdtype())


def _logits_sharding(cfg, mesh, batch_size):
    dp = rules.batch_spec(mesh)
    dp_axis = dp[0]
    dsize = 1
    if dp_axis is not None:
        axes = dp_axis if isinstance(dp_axis, tuple) else (dp_axis,)
        for a in axes:
            dsize *= mesh.shape[a]
    bshard = dp_axis if batch_size % dsize == 0 else None
    model = "model" if "model" in mesh.shape else None
    vshard = model if cfg.vocab_size % mesh.shape.get(model, 1) == 0 else None
    return NamedSharding(mesh, P(bshard, None, vshard))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             mesh=None, *, strategy: str = "fsdp",
             microbatches: int = 1,
             cfg_overrides: dict | None = None) -> dict:
    shape = SHAPES[shape_name]
    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    runnable, reason = shape_is_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "kind": shape.kind, "strategy": strategy,
            "microbatches": microbatches,
            "cfg_overrides": cfg_overrides or {}}
    if not runnable:
        cell.update(status="SKIP", reason=reason)
        return cell
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size

    t0 = time.time()
    with mesh:
        jitted, raw_fn, args = build_cell(arch, shape_name, mesh,
                                          strategy=strategy,
                                          microbatches=microbatches,
                                          cfg_overrides=cfg_overrides)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_info = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        try:
            mem_info[attr] = int(getattr(mem, attr))
        except (AttributeError, TypeError):
            pass

    # --- FLOPs: exact jaxpr count (XLA's cost_analysis visits scan bodies
    # once — see launch/jaxpr_flops.py; validated vs unrolled HLO to ~1.5%)
    with mesh:
        jflops, jbytes, trip_f, trip_b = jaxpr_flops.count_fn_with_factor(
            raw_fn, *args)
    flops_chip = jflops / chips
    # --- HBM bytes: fused HLO bytes (per device) x loop-trip factor
    hlo_flops_raw, hlo_bytes_raw = hlo_analysis.flops_and_bytes(compiled)
    hbm_bytes_chip = hlo_bytes_raw * trip_b
    # --- collective bytes: post-SPMD HLO parse with trip multiplication
    coll = hlo_analysis.collective_stats(compiled.as_text())

    # roofline terms (per-chip seconds)
    compute_s = flops_chip / PEAK_FLOPS_BF16
    memory_s = hbm_bytes_chip / HBM_BANDWIDTH
    collective_s = coll["total_bytes"] / ICI_BANDWIDTH

    # analytic minimum-traffic floor (perfectly fused; see launch/traffic.py)
    tp = mesh.shape.get("model", 1)
    floor = traffic.analytic_traffic(cfg, shape, chips, tp=tp,
                                     microbatches=microbatches)
    floor_memory_s = floor["total"] / HBM_BANDWIDTH

    # analytic model FLOPs: 6 * N_active * tokens (train fwd+bwd);
    # 2 * N_active * tokens for inference forward
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_active = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    model_flops_per_chip = model_flops / chips

    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    # the floor view: memory at the perfectly-fused minimum — what the
    # Pallas kernels deliver on hardware; §Perf drives the measured upper
    # bound toward this
    dominant_floor = max((("compute", compute_s),
                          ("memory", floor_memory_s),
                          ("collective", collective_s)),
                         key=lambda kv: kv[1])[0]
    bound_floor = max(compute_s, floor_memory_s, collective_s)
    cell.update(
        status="OK",
        chips=chips,
        analytic_memory_bytes=floor["total"],
        analytic_memory_term_s=floor_memory_s,
        analytic_breakdown={k: v for k, v in floor.items() if k != "total"},
        dominant_floor=dominant_floor,
        roofline_fraction_floor=compute_s / max(bound_floor, 1e-30),
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        hlo_flops_per_chip=flops_chip,
        hlo_bytes_per_chip=hbm_bytes_chip,
        hlo_flops_raw_body_once=hlo_flops_raw,
        hlo_bytes_raw_body_once=hlo_bytes_raw,
        loop_trip_factor=round(trip_f, 2),
        collective_bytes_per_chip=coll["total_bytes"],
        collective_by_kind=coll["by_kind"],
        compute_term_s=compute_s,
        memory_term_s=memory_s,
        collective_term_s=collective_s,
        dominant=dominant,
        model_flops_per_chip=model_flops_per_chip,
        useful_flop_ratio=(model_flops_per_chip / flops_chip)
        if flops_chip else None,
        memory_analysis=mem_info,
    )
    return cell





def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose JSON already exists in --out")
    ap.add_argument("--mode", default="tuned",
                    choices=["baseline", "tuned"],
                    help="baseline = no sharding hints / scatter MoE / "
                         "mb=1 / unblocked attention (the paper-faithful "
                         "naive distribution); tuned = §Perf configuration")
    args = ap.parse_args()

    if args.mode == "baseline":
        os.environ["REPRO_NO_HINTS"] = "1"

    def cell_knobs(arch: str, shape_name: str):
        """(run_cell kwargs) per §Perf tuning table."""
        if args.mode == "baseline":
            return {"cfg_overrides": {"moe_impl": "scatter",
                                      "attn_q_chunks": 1}}
        over = {}
        kw = {}
        cfg = configs.get_config(arch)
        if cfg.family == "moe":
            over["moe_impl"] = "einsum"
        if shape_name == "train_4k":
            # §Perf iteration 2: grad accumulation until temp < 16 GB HBM
            kw["microbatches"] = 8 if cfg.param_count() > 1e11 else 4
        if shape_name == "prefill_32k" and cfg.attention_layers:
            over["attn_q_chunks"] = 8       # blocked attention (§Perf)
        if over:
            kw["cfg_overrides"] = over
        return kw

    archs = configs.all_archs() if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch} x {shape_name} x {mesh_name} [{args.mode}]"
                path = None
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    suffix = "" if args.mode == "tuned" else "_baseline"
                    name = (f"dryrun_{arch}_{shape_name}_{mesh_name}"
                            f"{suffix}.json")
                    path = os.path.join(args.out, name.replace("/", "_"))
                if args.resume and path and os.path.exists(path):
                    with open(path) as f:
                        cell = json.load(f)
                    if cell.get("status") in ("OK", "SKIP"):
                        results.append(cell)
                        print(f"[CACHED {cell['status']}] {tag}")
                        continue
                try:
                    cell = run_cell(arch, shape_name, multi, mesh=mesh,
                                    **cell_knobs(arch, shape_name))
                except Exception as e:  # record and continue — unattended run
                    cell = {"arch": arch, "shape": shape_name,
                            "mesh": mesh_name, "status": "FAIL",
                            "error": f"{type(e).__name__}: {e}"}
                results.append(cell)
                if cell["status"] == "SKIP":
                    print(f"[SKIP] {tag}: {cell['reason']}")
                elif cell["status"] == "FAIL":
                    print(f"[FAIL] {tag}: {cell['error'][:400]}")
                else:
                    print(f"[OK]   {tag}: compile={cell['compile_s']}s "
                          f"flops/chip={cell['hlo_flops_per_chip']:.3e} "
                          f"coll_bytes/chip={cell['collective_bytes_per_chip']:.3e} "
                          f"dominant={cell['dominant']}", flush=True)
                if path:
                    with open(path, "w") as f:
                        json.dump(cell, f, indent=2)
    ok = [c for c in results if c["status"] == "OK"]
    skip = [c for c in results if c["status"] == "SKIP"]
    fail = [c for c in results if c["status"] == "FAIL"]
    print(f"\n{len(ok)}/{len(results)} cells compiled "
          f"({len(skip)} documented skips, {len(fail)} FAILURES)")


if __name__ == "__main__":
    main()
