"""Training driver: data -> sharded train_step -> checkpoints -> planner.

Production path (real TPU pod): the same script runs under
``jax.distributed.initialize`` with the 16x16 or 2x16x16 production mesh.
On this CPU container the examples run reduced configs on a small mesh —
same code path end to end, including:

  * auto-resume from the newest checkpoint (fault tolerance: kill/relaunch
    continues bit-exact),
  * the game-theoretic expert PartitionPlanner re-permuting MoE experts
    from live router stats every ``--replan`` steps,
  * optional pipeline-stage planning report (dense archs) via the same game.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.sharding import rules
from repro.sharding.planner import PartitionPlanner
from repro.training import checkpoint
from repro.training.data import SyntheticDataConfig, synthetic_batch
from repro.training.train_step import (TrainHyper, init_train_state,
                                       make_train_step)


def make_mesh_from_devices():
    """Largest (data, model) mesh the available devices allow."""
    n = len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0 and cand <= n:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def train(arch: str, *, smoke: bool = True, steps: int = 50,
          global_batch: int = 8, seq_len: int = 128,
          ckpt_dir: str | None = None, ckpt_every: int = 20,
          replan: int = 0, microbatches: int = 1,
          schedule: str | None = None, log_every: int = 10,
          mesh=None, seed: int = 0):
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    if mesh is None:
        mesh = make_mesh_from_devices()
    hyper = TrainHyper(
        total_steps=steps, warmup=max(steps // 10, 1),
        microbatches=microbatches,
        schedule=schedule or ("wsd" if "minicpm" in arch else "cosine"),
        wsd_stable=int(steps * 0.6), wsd_decay=int(steps * 0.3))
    step_fn = make_train_step(cfg, hyper)

    state = init_train_state(cfg, jax.random.PRNGKey(seed))
    start_step = 0
    if ckpt_dir:
        restored, at = checkpoint.restore(ckpt_dir, state)
        if restored is not None:
            state, start_step = restored, at
            print(f"[train] resumed from checkpoint step {at}")

    state_sh = rules.state_shardings(cfg, mesh, state)
    state = jax.device_put(state, state_sh)
    data_cfg = SyntheticDataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len,
        global_batch=global_batch, seed=seed,
        input_kind=cfg.input_kind, d_model=cfg.d_model)

    jitted = jax.jit(step_fn, in_shardings=(state_sh, None),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    planner = PartitionPlanner(num_groups=mesh.shape.get("model", 1),
                               interval=replan) if replan else None

    losses = []
    t0 = time.time()
    with mesh:
        for step in range(start_step, steps):
            batch = synthetic_batch(data_cfg, step)
            state, metrics = jitted(state, batch)
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step={step} loss={float(metrics['loss']):.4f}"
                      f" ce={float(metrics['ce']):.4f}"
                      f" gnorm={float(metrics['grad_norm']):.3f}"
                      f" lr={float(metrics['lr']):.2e}")
            if planner is not None:
                state, stats = planner.maybe_replan(step + 1, state)
                if stats:
                    print(f"[planner] step={step + 1} expert rebalance: "
                          f"imbalance {stats['imbalance_before']:.3f} -> "
                          f"{stats['imbalance_after']:.3f} "
                          f"({stats['moves']} moves)")
                    state = jax.device_put(state, state_sh)
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                checkpoint.save(ckpt_dir, step + 1, state)
    wall = time.time() - t0
    print(f"[train] {steps - start_step} steps in {wall:.1f}s "
          f"({(steps - start_step) / max(wall, 1e-9):.2f} it/s); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--replan", type=int, default=0,
                    help="expert-placement replan interval (0 = off)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--schedule", default=None, choices=[None, "cosine", "wsd"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    train(args.arch, smoke=args.smoke, steps=args.steps,
          global_batch=args.batch, seq_len=args.seq,
          ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
          replan=args.replan, microbatches=args.microbatches,
          schedule=args.schedule, seed=args.seed)


if __name__ == "__main__":
    main()
