"""Serving driver: continuous batching over the slot-pool engine.

Production path (real TPU pod): params come from a training checkpoint and
shard per `repro.sharding.rules` (model-only for inference — §Perf
iteration 5); on this CPU container the example serves a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b \
      --requests 16 --max-new 24 --slots 4
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro import configs
from repro.models import init_params
from repro.serving import Request, ServeConfig, ServingEngine
from repro.serving.sampler import sample_logits
from repro.training import checkpoint


def serve(arch: str, *, smoke: bool = True, requests: int = 16,
          max_new: int = 24, slots: int = 4, max_len: int = 256,
          temperature: float = 0.0, ckpt_dir: str | None = None,
          seed: int = 0):
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    if ckpt_dir:
        restored, step = checkpoint.restore(ckpt_dir, params)
        if restored is not None:
            params = restored
            print(f"[serve] loaded checkpoint step {step}")

    sampler = None
    if temperature > 0:
        key = jax.random.PRNGKey(seed + 1)
        sampler = lambda logits: sample_logits(key, logits,
                                               temperature=temperature)
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=slots, max_len=max_len,
                                    cache_dtype="float32"),
                        **({"sampler": sampler} if sampler else {}))
    rng = np.random.default_rng(seed)
    for i in range(requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(4, max(5, max_len // 8)))
                              ).astype(np.int32)
        eng.submit(Request(i, prompt, max_new_tokens=max_new))
    stats = eng.run()
    print(f"[serve] {stats['requests']} requests | "
          f"{stats['generated_tokens']} tokens | "
          f"{stats['decode_steps']} batched decode steps | "
          f"{stats['tok_per_s']:.1f} tok/s")
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve(args.arch, smoke=args.smoke, requests=args.requests,
          max_new=args.max_new, slots=args.slots, max_len=args.max_len,
          temperature=args.temperature, ckpt_dir=args.ckpt_dir,
          seed=args.seed)


if __name__ == "__main__":
    main()
