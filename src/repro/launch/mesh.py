"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (jax locks the device count on first init, and
smoke tests must see 1 CPU device while the dry-run sees 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / reduced dry-runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BANDWIDTH = 819e9           # B/s
ICI_BANDWIDTH = 50e9            # B/s per link
