"""Analytic minimum-HBM-traffic model per (arch x shape) cell.

XLA's ``cost_analysis()['bytes accessed']`` counts every operand of every
HLO op — an UNFUSED UPPER BOUND that (on the CPU backend used for the
dry-run) overstates TPU HBM traffic by an order of magnitude.  For the
roofline's memory term we therefore also derive a perfectly-fused FLOOR
from first principles; the truth on hardware lies between the two, and the
§Perf iteration drives the measured upper bound toward this floor.

Model (per chip, per step; tp = model-axis size, dp = chips / tp):

train (microbatched, remat-per-layer, flash-style attention):
  * weights      — FSDP gather write+read per pass per microbatch of the
                   chip's model shard: 2 * 2 * mb * P/tp * bytes_p
  * optimizer    — read grad + m + v, write m + v + p (f32 moments):
                   P/chips * (4 + 4+4 + 4+4 + bytes_p)
  * activations  — saved residuals (seq-sharded): 3 * L * T_loc * d * b_c
                   (write fwd, read bwd, recompute traffic)
  * attention    — flash floor: QKVO streams, ~4 * T_loc * h*hd * b_c * 2
                   passes (the S x S logits never hit HBM)
  * MoE dispatch — routed copies in/out: 4 * T_loc * k * d * b_c per pass

prefill:  weights once + KV-cache write + activation stream.
decode:   weights once per token + KV-cache (or SSM state) read + write.

T_loc = tokens / chips for fully-sharded activations (batch over dp,
sequence over tp — the layout the hints enforce).
"""
from __future__ import annotations

from ..models.config import HYBRID, MOE, SSM


def analytic_traffic(cfg, shape, chips: int, tp: int = 16,
                     microbatches: int = 1) -> dict:
    bytes_p = 2 if cfg.param_dtype == "bfloat16" else 4
    bytes_c = 2 if cfg.compute_dtype == "bfloat16" else 4
    P = cfg.param_count() + cfg.shared_block_params()
    L = cfg.num_layers
    d = cfg.d_model
    dp = max(chips // tp, 1)
    B, S = shape.global_batch, shape.seq_len

    out = {}
    if shape.kind == "train":
        tokens = B * S
        t_loc = tokens / chips
        mb = microbatches
        out["weights"] = 2 * 2 * mb * (P / tp) * bytes_p
        out["optimizer"] = (P / chips) * (4 + 8 + 8 + bytes_p)
        out["activations"] = 3 * L * t_loc * d * bytes_c
        if cfg.num_heads:
            out["attention"] = 2 * 4 * t_loc * cfg.num_heads \
                * cfg.head_dim * bytes_c * (cfg.attention_layers / max(L, 1))
        if cfg.family == MOE:
            out["moe_dispatch"] = 2 * 4 * t_loc * cfg.top_k * d * bytes_c
        if cfg.family in (SSM, HYBRID):
            out["ssm_states"] = 3 * L * (B / dp) * cfg.ssm_heads \
                * cfg.ssm_head_dim * cfg.ssm_state * 4 / tp
    elif shape.kind == "prefill":
        tokens = B * S
        t_loc = tokens / chips
        out["weights"] = 2 * (P / tp) * bytes_p
        out["activations"] = L * t_loc * d * bytes_c * 2
        out["kv_write"] = _cache_bytes(cfg, B, S) / chips
    else:  # decode: one token against a seq_len cache
        out["weights"] = (P / tp) * bytes_p \
            if cfg.family != MOE else (_moe_active_params(cfg, B) / tp) * bytes_p
        out["cache_read"] = _cache_bytes(cfg, B, S) / chips
        out["cache_write"] = _cache_step_bytes(cfg, B) / chips

    out["total"] = sum(out.values())
    return out


def _cache_bytes(cfg, B: int, S: int) -> float:
    """Full KV/SSM cache size (bf16 KV, f32 SSM state)."""
    total = 0.0
    if cfg.attention_layers:
        total += (2 * cfg.attention_layers * B * S * cfg.num_kv_heads
                  * cfg.head_dim * 2)
    if cfg.family in (SSM, HYBRID):
        total += (cfg.num_layers * B * cfg.ssm_heads * cfg.ssm_head_dim
                  * cfg.ssm_state * 4)
        total += cfg.num_layers * B * (cfg.ssm_conv - 1) \
            * (cfg.d_inner + 2 * cfg.ssm_state) * 2
    return total


def _cache_step_bytes(cfg, B: int) -> float:
    """Bytes written per decode step (one new KV slot / state update)."""
    total = 0.0
    if cfg.attention_layers:
        total += 2 * cfg.attention_layers * B * cfg.num_kv_heads \
            * cfg.head_dim * 2
    if cfg.family in (SSM, HYBRID):
        total += cfg.num_layers * B * cfg.ssm_heads * cfg.ssm_head_dim \
            * cfg.ssm_state * 4 * 2      # state read+write
    return total


def _moe_active_params(cfg, batch: int) -> float:
    """Expected parameter bytes touched per decode step: dense part plus
    the experts actually hit by B tokens x top_k draws."""
    expert_p = cfg.num_experts * 3 * cfg.d_model * cfg.d_ff * cfg.num_layers
    dense_p = cfg.param_count() - expert_p
    e = cfg.num_experts
    draws = batch * cfg.top_k
    hit_frac = 1.0 - (1.0 - 1.0 / e) ** draws      # E[experts hit] / e
    return dense_p + expert_p * hit_frac
