"""Jitted public wrappers around the Pallas kernels.

``interpret`` defaults to backend auto-detection (interpret mode unless the
default backend is a real TPU); pass an explicit bool, or set
REPRO_PALLAS_COMPILE=1, to override.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from ..core.refine import DissatFn, SweepCandidateFn
from . import ref
from .decode_attention import decode_attention_pallas
from .dissatisfaction import (cost_matrix_pallas,
                              dissatisfaction_from_aggregate_batched_pallas,
                              dissatisfaction_from_aggregate_pallas,
                              resolve_interpret)

Array = jax.Array

# Declared asymptotic budgets for the kernel-reduction entry points,
# consumed by the complexity analyzers (DESIGN.md §18) and keyed by
# registered entry-point name.  The dense aggregate kernel consumes the
# (N, N) adjacency (dense budget); the edge kernel streams fixed tiles
# of the COO edge list, so its peak intermediate is O(E) and its work
# O(E * K) — the same contract as the jnp sparse path it replaces.
KERNEL_COMPLEXITY = {
    "refine.kernel": {
        "mem": {"n": 2.0, "k": 1.0},
        "ops": {"n": 2.0, "k": 1.0},
    },
    "refine.sparse.edge_kernel": {
        "mem": {"n": 1.0, "e": 1.0, "k": 1.0},
        "ops": {"n": 1.0, "e": 1.0, "k": 1.0},
    },
}


def _default_interpret() -> bool:
    return resolve_interpret(None)


@partial(jax.jit, static_argnames=("framework", "interpret"))
def cost_matrix(adjacency: Array, assignment: Array, node_weights: Array,
                loads: Array, speeds: Array, mu, framework: str = "c",
                interpret: bool | None = None) -> Array:
    """(N, K) node-cost matrix via the fused Pallas kernel."""
    if interpret is None:
        interpret = _default_interpret()
    return cost_matrix_pallas(adjacency, assignment, node_weights, loads,
                              speeds, mu, framework, interpret=interpret)


@partial(jax.jit, static_argnames=("framework",))
def cost_matrix_reference(adjacency: Array, assignment: Array,
                          node_weights: Array, loads: Array, speeds: Array,
                          mu, framework: str = "c") -> Array:
    return ref.cost_matrix_ref(adjacency, assignment, node_weights, loads,
                               speeds, mu, framework)


def make_core_cost_matrix_fn(interpret: bool | None = None):
    """Adapter with the (problem, state, framework) signature expected by
    repro.core.refine(..., cost_matrix_fn=...), so the recompute-path
    refinement loop can run on the Pallas kernel instead of the jnp path."""
    def fn(problem, state, framework):
        return cost_matrix(problem.adjacency, state.assignment,
                           problem.node_weights, state.loads, problem.speeds,
                           problem.mu, framework, interpret=interpret)
    return fn


@lru_cache(maxsize=None)
def _vmappable_aggregate_dissat(framework: str, interpret: bool):
    """The fused aggregate→(dissat, best) reduction as a ``custom_vmap``
    callable: called plain it runs the unbatched Pallas kernel; under
    ``jax.vmap`` it runs the batch-grid kernel
    (:func:`~repro.kernels.dissatisfaction.dissatisfaction_from_aggregate_batched_pallas`,
    DESIGN.md §12.3) instead of an unrolled per-element fallback.  All
    operands are arrays (``theta`` rides as explicit zeros when absent —
    bitwise identical, the kernel always subtracts its theta operand)."""

    @jax.custom_batching.custom_vmap
    def fn(aggregate, row_assignment, node_weights, loads, speeds, mu,
           total_weight, theta):
        return dissatisfaction_from_aggregate_pallas(
            aggregate, row_assignment, node_weights, loads, speeds, mu,
            framework, theta=theta, total_weight=total_weight,
            interpret=interpret)

    @fn.def_vmap
    def _batch_rule(axis_size, in_batched, *args):
        stacked = [x if hit else
                   jnp.broadcast_to(x, (axis_size,) + jnp.shape(x))
                   for x, hit in zip(args, in_batched)]
        agg, r_rows, b, loads, speeds, mu, total_w, theta = stacked
        out = dissatisfaction_from_aggregate_batched_pallas(
            agg, r_rows, b, loads, speeds, mu, framework, theta=theta,
            total_weight=total_w, interpret=interpret)
        return out, (True, True)

    return fn


@partial(jax.jit, static_argnames=("framework", "interpret"))
def dissatisfaction_from_aggregate(aggregate: Array, row_assignment: Array,
                                   node_weights: Array, loads: Array,
                                   speeds: Array, mu, total_weight,
                                   framework: str = "c",
                                   theta: Array | None = None,
                                   interpret: bool | None = None):
    """(dissat, best_machine) from a carried aggregate via the fused kernel
    — the incremental refinement hot path (no (N, K) cost matrix in HBM).
    ``theta`` (rows,) subtracts the per-node migration price inside the
    fused reduction (DESIGN.md §11); the result is net dissatisfaction.
    Under ``jax.vmap`` (the batched sweep runtime, DESIGN.md §12) this
    dispatches to the batch-grid kernel, staying one fused program."""
    if interpret is None:
        interpret = _default_interpret()
    rows = jnp.shape(row_assignment)[-1]
    if theta is None:
        theta = jnp.zeros((rows,), jnp.float32)
    else:
        theta = jnp.broadcast_to(jnp.asarray(theta, jnp.float32), (rows,))
    return _vmappable_aggregate_dissat(framework, interpret)(
        jnp.asarray(aggregate), jnp.asarray(row_assignment, jnp.int32),
        jnp.asarray(node_weights), jnp.asarray(loads), jnp.asarray(speeds),
        jnp.asarray(mu, jnp.float32), jnp.asarray(total_weight, jnp.float32),
        theta)


def make_edge_dissat_fn(problem, interpret: bool | None = None) -> DissatFn:
    """The ``dissat_fn`` convention (see :mod:`repro.core.refine`) on the
    fused Pallas EDGE-BLOCK kernel (DESIGN.md §13.3): the per-turn
    reduction is recomputed straight from ``problem``'s edge list — the
    carried ``aggregate`` argument is ignored, making this the
    drift-free sparse oracle (nothing accumulates across turns), at
    O(E·K) kernel work per turn instead of the aggregate kernel's
    O(N·K) read.  ``problem`` is a concrete
    :class:`~repro.core.sparse.SparseProblem`; its edge-tile layout is
    built host-side once here and closed over.  Plugs into
    ``repro.core.refine(..., dissat_fn=...)`` like any other; unbatched
    only (the batched sweep runtime keeps the aggregate kernel).
    """
    from .edge_block import (build_edge_tile_layout,
                             dissatisfaction_from_edges_pallas)
    layout = build_edge_tile_layout(problem)

    def fn(aggregate, assignment, node_weights, loads, speeds, mu,
           framework, total_weight, theta=None):
        del aggregate   # recomputed from edges — see docstring
        return dissatisfaction_from_edges_pallas(
            layout, assignment, node_weights, loads, speeds, mu, framework,
            theta=theta, total_weight=total_weight, interpret=interpret)
    return fn


def make_edge_sweep_fn(problem,
                       interpret: bool | None = None) -> SweepCandidateFn:
    """The :class:`~repro.core.refine.SweepCandidateFn` convention on the
    fused Pallas edge-block SWEEP kernel (DESIGN.md §17.4): one edge
    stream per sweep produces the whole per-machine election
    ``(gains, picks, dests)`` — the carried ``aggregate`` argument is
    ignored (recomputed from edges, drift-free like
    :func:`make_edge_dissat_fn`), and only O(T·K) election partials ever
    leave the kernel.  ``problem`` is a concrete
    :class:`~repro.core.sparse.SparseProblem`; its edge-tile layout is
    built host-side once here and closed over.  Plugs into
    ``repro.core.refine_sweeps(..., sweep_fn=...)``
    (``moves_per_machine=1`` only — the election IS one per machine).
    """
    from .edge_block import (build_edge_tile_layout,
                             sweep_candidates_from_edges_pallas)
    layout = build_edge_tile_layout(problem)

    def fn(aggregate, assignment, node_weights, loads, speeds, mu,
           framework, total_weight, theta=None):
        del aggregate   # recomputed from edges — see docstring
        return sweep_candidates_from_edges_pallas(
            layout, assignment, node_weights, loads, speeds, mu, framework,
            theta=theta, total_weight=total_weight, interpret=interpret)
    return fn


def make_timed_dissat_fn(dissat_fn: DissatFn, recorder,
                         name: str = "kernels.dissat") -> DissatFn:
    """Wrap a ``dissat_fn`` with recorder phase timing (DESIGN.md §14.3).

    Eager calls are wall-clocked — ``recorder.phase(name)`` around the
    call plus ``block_until_ready`` so the span covers device execution,
    not just dispatch.  Calls made under tracing (any argument a
    ``Tracer``) pass straight through untimed: inside jit the Python
    call runs once at trace time and a wall-clock there measures
    nothing, so the jaxpr stays identical to the unwrapped function's.
    Follows the same 9-argument ``dissat_fn`` convention as the wrapped
    callable, so it plugs into ``repro.core.refine(..., dissat_fn=...)``
    anywhere the original does.
    """
    def fn(*args, **kwargs):
        leaves = jax.tree.leaves((args, kwargs))
        if any(isinstance(leaf, jax.core.Tracer) for leaf in leaves):
            return dissat_fn(*args, **kwargs)
        with recorder.phase(name):
            out = dissat_fn(*args, **kwargs)
            jax.block_until_ready(out)
        return out
    return fn


def make_aggregate_dissat_fn(interpret: bool | None = None) -> DissatFn:
    """Adapter implementing THE ``dissat_fn`` calling convention — see the
    canonical 9-argument spec in :mod:`repro.core.refine` ("The
    ``dissat_fn`` convention") — on the fused Pallas kernel, so the
    incremental loop's per-turn reduction never materializes the (N, K)
    cost matrix.  Plugs into ``repro.core.refine(..., dissat_fn=...)``
    and the distributed shards alike, batched or not (DESIGN.md §12.3).
    """
    def fn(aggregate, assignment, node_weights, loads, speeds, mu,
           framework, total_weight, theta=None):
        return dissatisfaction_from_aggregate(
            aggregate, assignment, node_weights, loads, speeds, mu,
            total_weight, framework, theta=theta, interpret=interpret)
    return fn


@partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q: Array, k: Array, v: Array, length: Array,
                     interpret: bool | None = None) -> Array:
    """GQA single-token decode attention (flash-decoding style)."""
    if interpret is None:
        interpret = _default_interpret()
    return decode_attention_pallas(q, k, v, length, interpret=interpret)


decode_attention_reference = jax.jit(ref.decode_attention_ref)


@partial(jax.jit, static_argnames=("interpret",))
def flash_attention(q: Array, k: Array, v: Array,
                    interpret: bool | None = None) -> Array:
    """Blocked causal GQA attention (flash-attention forward) — the
    train/prefill hot-spot kernel; S x S logits never touch HBM."""
    from .flash_attention import flash_attention_pallas
    if interpret is None:
        interpret = _default_interpret()
    return flash_attention_pallas(q, k, v, interpret=interpret)


flash_attention_reference = jax.jit(ref.flash_attention_ref)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: Array, dt: Array, a: Array, bm: Array, cm: Array,
             chunk: int = 128, interpret: bool | None = None):
    """Mamba2 SSD chunked scan — the SSM train/prefill hot-spot kernel;
    the recurrent state lives in VMEM across chunks."""
    from .ssd_scan import ssd_scan_pallas
    if interpret is None:
        interpret = _default_interpret()
    return ssd_scan_pallas(x, dt, a, bm, cm, chunk, interpret=interpret)


ssd_scan_reference = jax.jit(ref.ssd_scan_ref)
