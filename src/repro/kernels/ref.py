"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations every kernel is validated
against (tests sweep shapes/dtypes and assert allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def cost_matrix_ref(adjacency: Array, assignment: Array, node_weights: Array,
                    loads: Array, speeds: Array, mu, framework: str) -> Array:
    """(N, K) node-cost matrix — reference for kernels/dissatisfaction.py.

    Mirrors repro.core.costs.cost_matrix but takes raw arrays (the kernel
    layer is independent of the problem containers).
    """
    K = speeds.shape[0]
    f32 = jnp.float32
    adjacency = adjacency.astype(f32)
    b = node_weights.astype(f32)
    onehot = jax.nn.one_hot(assignment, K, dtype=f32)
    aggregate = adjacency @ onehot                               # (N, K)
    degree = jnp.sum(aggregate, axis=-1, keepdims=True)
    own = onehot
    others = loads.astype(f32)[None, :] - b[:, None] * own
    cut_term = 0.5 * jnp.asarray(mu, f32) * (degree - aggregate)
    inv_w = 1.0 / speeds.astype(f32)[None, :]
    if framework == "c":
        return (b[:, None] * inv_w) * others + cut_term
    if framework == "ct":
        total = jnp.sum(b)
        return ((b[:, None] ** 2) * inv_w**2
                + 2.0 * b[:, None] * inv_w**2 * others
                - 2.0 * b[:, None] * inv_w * total) + cut_term
    raise ValueError(framework)


def decode_attention_ref(q: Array, k: Array, v: Array, length) -> Array:
    """Single-token decode attention — reference for kernels/decode_attention.py.

    q: (B, H, D); k/v: (B, S, Hkv, D) with Hkv | H (GQA); ``length`` (B,)
    gives the valid prefix of the cache.  Returns (B, H, D).
    """
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, D)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
    mask = jnp.arange(S)[None, None, None, :] < jnp.asarray(length)[:, None, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def flash_attention_ref(q: Array, k: Array, v: Array) -> Array:
    """Causal GQA attention — reference for kernels/flash_attention.py.

    q: (B, S, H, D); k/v: (B, S, Hkv, D) with Hkv | H.  Full-materialized
    f32 softmax over the S x S logits (the thing the kernel never builds).
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def ssd_scan_ref(x: Array, dt: Array, a: Array, bm: Array, cm: Array):
    """Naive per-token SSD recurrence — reference for kernels/ssd_scan.py.

    s_t = exp(dt_t a) s_{t-1} + dt_t · B_t ⊗ x_t;  y_t = C_t · s_t.
    Independent of the chunked formulation (pure sequential scan).
    """
    B, L, H, P = x.shape

    def step(s, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a[None, :])                        # (B, H)
        s = s * decay[:, :, None, None] \
            + jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", ct, s)
        return s, y

    s0 = jnp.zeros((B, H, P, bm.shape[-1]), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(cm.astype(jnp.float32), 1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), final
