"""Fused Pallas TPU kernels for the refinement hot spot (DESIGN.md §3.2, §10).

Three kernels:

* :func:`cost_matrix_pallas` — the recompute path.  Every from-scratch
  cost evaluation needs the aggregate  A[i, k] = sum_j c_ij * 1[r_j = k]
  — an (N x N) @ (N x K) matmul.  Computing A with jnp and then assembling
  costs reads the (N, K) intermediates from HBM several times; this kernel
  tiles the adjacency into VMEM blocks, accumulates A on the MXU, and
  fuses the entire cost assembly (load term + cut term for either
  framework) into the final grid step, so the adjacency is read exactly
  once and nothing but the (N, K) cost matrix is written back.

  Grid: (N/TN, N/TJ), j innermost.  Per (i, j) step:
    * build the one-hot of the column block's assignments (TJ, K) in VREGs,
    * acc(TN, K) += C_block(TN, TJ) @ onehot  (MXU),
    * at j == last: assemble the cost block and write it out.

* :func:`dissatisfaction_from_aggregate_pallas` — the incremental path
  (DESIGN.md §10).  The refinement loop already carries A, so no matmul is
  needed at all: this kernel reads the (N, K) aggregate once, assembles
  the cost block in VREGs, and reduces it to the Eq.-4 dissatisfaction and
  arg-best machine in the same grid step — the (N, K) cost matrix never
  touches HBM.  Per-turn kernel traffic is O(NK) in, O(N) out.

* :func:`dissatisfaction_from_aggregate_batched_pallas` — the same fused
  reduction over a STACK of B independent problems (DESIGN.md §12.3).
  The grid grows a leading batch dimension, ``grid=(B, rows/TN)``, and
  every operand's BlockSpec indexes its element's slab, so one
  ``pallas_call`` serves a whole scenario fleet while each (b, i) step
  runs the identical op sequence on the identical tile the unbatched
  kernel would see — per-element outputs are bitwise those of B separate
  unbatched calls.  ``repro.kernels.ops`` routes ``jax.vmap`` of the
  unbatched entry point here via ``jax.custom_batching.custom_vmap``, so
  the batched sweep runtime (:mod:`repro.sweeps`) keeps the hot path
  fused instead of falling back to an unrolled per-element kernel.

All tile dims are multiples of the 128-lane MXU width; K is padded to 128
lanes by the wrappers.

``interpret`` defaults to backend auto-detection (:func:`resolve_interpret`):
interpret mode everywhere except a real TPU backend, overridable explicitly
or via ``REPRO_PALLAS_COMPILE=1``.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_TILE_N = 128
DEFAULT_TILE_J = 128

_BIG = 3.0e38   # finite "+inf" for masked K lanes (0*inf = nan)


def resolve_interpret(interpret: bool | None) -> bool:
    """Backend auto-detection for the ``interpret`` flag: explicit values
    win; ``REPRO_PALLAS_COMPILE=1`` forces compiled; otherwise interpret
    everywhere except a real TPU backend."""
    if interpret is not None:
        return interpret
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    return jax.default_backend() != "tpu"


def _kernel(c_ref, r_cols_ref, r_rows_ref, b_rows_ref, loads_ref, speeds_ref,
            scalars_ref, out_ref, acc_ref, *, framework: str, num_j: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kpad = loads_ref.shape[-1]
    r_cols = r_cols_ref[0, :]                                  # (TJ,) int32
    onehot = (r_cols[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, kpad), 1)
              ).astype(jnp.float32)                            # (TJ, K)
    acc_ref[...] += jax.lax.dot(
        c_ref[...].astype(jnp.float32), onehot,
        preferred_element_type=jnp.float32)

    @pl.when(j == num_j - 1)
    def _finish():
        aggregate = acc_ref[...]                               # (TN, K)
        mu = scalars_ref[0, 0]
        total_b = scalars_ref[0, 1]
        b = b_rows_ref[0, :].astype(jnp.float32)[:, None]      # (TN, 1)
        r_rows = r_rows_ref[0, :]                              # (TN,)
        own = (r_rows[:, None]
               == jax.lax.broadcasted_iota(jnp.int32, (1, kpad), 1)
               ).astype(jnp.float32)
        loads = loads_ref[0, :][None, :]                       # (1, K)
        inv_w = 1.0 / speeds_ref[0, :][None, :]
        degree = jnp.sum(aggregate, axis=-1, keepdims=True)
        others = loads - b * own
        cut_term = 0.5 * mu * (degree - aggregate)
        if framework == "c":
            cost = (b * inv_w) * others + cut_term
        else:
            cost = (b * b) * inv_w * inv_w \
                + 2.0 * b * inv_w * inv_w * others \
                - 2.0 * b * inv_w * total_b + cut_term
        out_ref[...] = cost


def cost_matrix_pallas(adjacency: Array, assignment: Array, node_weights: Array,
                       loads: Array, speeds: Array, mu,
                       framework: str = "c", *,
                       tile_n: int = DEFAULT_TILE_N,
                       tile_j: int = DEFAULT_TILE_J,
                       interpret: bool | None = None,
                       row_assignment: Array | None = None,
                       total_weight: Array | None = None) -> Array:
    """Padded + tiled pallas_call; returns the (rows, K) cost matrix.

    ``adjacency`` may be rectangular: a ``(rows, N)`` row block of a larger
    graph, as produced by :mod:`repro.distributed.views` — the grid tiles
    rows and columns independently and the contraction runs over the full
    column extent, so each machine of the distributed runtime can drive
    this same kernel on nothing but its shard.  In the row-block case pass
    ``row_assignment`` (length ``rows``, the block nodes' own machines;
    ``assignment`` then covers the N *columns*), ``node_weights`` of length
    ``rows``, and ``total_weight`` = the global sum of b (the Ct framework
    needs B, which a row block cannot compute locally).  Square callers
    keep the original signature: both default to ``assignment`` /
    ``sum(node_weights)``.

    ``interpret=None`` auto-detects (interpret mode unless the backend is
    a real TPU — see :func:`resolve_interpret`); pass an explicit bool to
    override.
    """
    interpret = resolve_interpret(interpret)
    n_rows, n_cols = adjacency.shape
    k = loads.shape[0]
    if row_assignment is None:
        row_assignment = assignment
    if total_weight is None:
        total_weight = jnp.sum(node_weights)
    rows_pad = -(-n_rows // tile_n) * tile_n
    cols_pad = -(-n_cols // tile_j) * tile_j
    k_pad = -(-k // 128) * 128

    c = jnp.zeros((rows_pad, cols_pad), adjacency.dtype)
    c = c.at[:n_rows, :n_cols].set(adjacency)
    # padded rows/columns point at a padded machine so they never pollute
    # real K (and padded rows carry zero weight)
    r_cols = jnp.full((1, cols_pad), k_pad - 1, jnp.int32).at[0, :n_cols].set(
        jnp.asarray(assignment, jnp.int32))
    r_rows = jnp.full((1, rows_pad), k_pad - 1, jnp.int32).at[0, :n_rows].set(
        jnp.asarray(row_assignment, jnp.int32))
    b = jnp.zeros((1, rows_pad), jnp.float32).at[0, :n_rows].set(
        node_weights.astype(jnp.float32))
    l_pad = jnp.zeros((1, k_pad), jnp.float32).at[0, :k].set(
        loads.astype(jnp.float32))
    w_pad = jnp.ones((1, k_pad), jnp.float32).at[0, :k].set(
        speeds.astype(jnp.float32))
    scalars = jnp.stack([jnp.asarray(mu, jnp.float32),
                         jnp.asarray(total_weight, jnp.float32)])[None, :]

    num_i = rows_pad // tile_n
    num_j = cols_pad // tile_j
    out = pl.pallas_call(
        functools.partial(_kernel, framework=framework, num_j=num_j),
        grid=(num_i, num_j),
        in_specs=[
            pl.BlockSpec((tile_n, tile_j), lambda i, j: (i, j)),   # adjacency
            pl.BlockSpec((1, tile_j), lambda i, j: (0, j)),        # r (cols)
            pl.BlockSpec((1, tile_n), lambda i, j: (0, i)),        # r (rows)
            pl.BlockSpec((1, tile_n), lambda i, j: (0, i)),        # b (rows)
            pl.BlockSpec((1, k_pad), lambda i, j: (0, 0)),         # loads
            pl.BlockSpec((1, k_pad), lambda i, j: (0, 0)),         # speeds
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),             # mu, B
        ],
        out_specs=pl.BlockSpec((tile_n, k_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, k_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile_n, k_pad), jnp.float32)],
        interpret=interpret,
    )(c, r_cols, r_rows, b, l_pad, w_pad, scalars)
    return out[:n_rows, :k]


# ---------------------------------------------------------------------------
# incremental path: (dissat, best) straight from the carried aggregate
# ---------------------------------------------------------------------------

def reduce_dissat_tile(aggregate, r_rows, b_rows, theta_rows, loads_row,
                       speeds_row, mu, total_b, *, framework: str,
                       k_real: int):
    """THE fused cost-assembly + Eq.-4 reduction over one (TN, K) tile,
    shared (same ops, same order — the bitwise contract) by every kernel
    that ends in a dissatisfaction reduction: the aggregate kernels here
    and the edge-block kernel of :mod:`repro.kernels.edge_block`.

    Returns ``(dissat (TN,), best (TN,))``: net-of-theta dissatisfaction
    (DESIGN.md §11) and the lowest-index arg-best machine (§7).
    """
    tn, kpad = aggregate.shape
    b = b_rows.astype(jnp.float32)[:, None]                    # (TN, 1)
    kidx = jax.lax.broadcasted_iota(jnp.int32, (tn, kpad), 1)
    own = (r_rows[:, None] == kidx).astype(jnp.float32)
    loads = loads_row[None, :]                                 # (1, K)
    inv_w = 1.0 / speeds_row[None, :]
    degree = jnp.sum(aggregate, axis=-1, keepdims=True)
    others = loads - b * own
    cut_term = 0.5 * mu * (degree - aggregate)
    if framework == "c":
        cost = (b * inv_w) * others + cut_term
    else:
        cost = (b * b) * inv_w * inv_w \
            + 2.0 * b * inv_w * inv_w * others \
            - 2.0 * b * inv_w * total_b + cut_term
    # Padded K lanes must not win the min; keep them finite (0 * inf = nan).
    cost = jnp.where(kidx < k_real, cost, _BIG)
    best_val = jnp.min(cost, axis=1)
    # lowest-index argmin (DESIGN.md §7) via the iota-min trick
    best_idx = jnp.min(jnp.where(cost <= best_val[:, None], kidx, kpad),
                       axis=1).astype(jnp.int32)
    current = jnp.sum(jnp.where(own > 0, cost, 0.0), axis=1)
    # net-of-migration-price Eq. 4 (DESIGN.md §11); theta rows default to 0
    return current - best_val - theta_rows, best_idx


def reduce_sweep_tile(aggregate, r_rows, b_rows, theta_rows, loads_row,
                      speeds_row, mu, total_b, row_base, *, framework: str,
                      k_real: int, n_real: int):
    """The per-MACHINE sweep election over one (TN, K) tile (DESIGN.md
    §17.4) — EXTENDS :func:`reduce_dissat_tile` (calls it first, so the
    per-node ``(dissat, best)`` semantics and tie-breaks stay in the one
    shared place) and then reduces the tile to each machine's election
    partials:

      * ``tile_gain (K,)`` — max net dissatisfaction among the tile's
        rows owned by machine k (``-_BIG`` when it owns none here);
      * ``tile_node (K,)`` — the GLOBAL id of that row (lowest row on
        ties — the same first-maximum tie-break ``jnp.argmax`` realizes
        on the jnp election path, via the iota-min trick);
      * ``tile_dest (K,)`` — that row's lowest-index arg-best machine.

    ``row_base`` is the tile's global row offset; rows at or beyond
    ``n_real`` (padding) are masked out of every election.  The host
    combine (argmax over the tile axis — first maximum = lowest tile,
    hence globally lowest node index) finishes the election.
    """
    dissat, best = reduce_dissat_tile(
        aggregate, r_rows, b_rows, theta_rows, loads_row, speeds_row, mu,
        total_b, framework=framework, k_real=k_real)
    tn, kpad = aggregate.shape
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (tn, kpad), 0)
    kidx = jax.lax.broadcasted_iota(jnp.int32, (tn, kpad), 1)
    valid = (row_base + row_iota) < n_real
    own = (r_rows[:, None] == kidx) & valid
    masked = jnp.where(own, dissat[:, None], -_BIG)            # (TN, K)
    tile_gain = jnp.max(masked, axis=0)
    # lowest winning row per machine (first-maximum tie-break)
    win = masked >= tile_gain[None, :]
    tile_row = jnp.min(jnp.where(win, row_iota, tn), axis=0)
    tile_node = (row_base + tile_row).astype(jnp.int32)
    # gather the winning row's best machine, again via the min trick
    tile_dest = jnp.min(jnp.where(row_iota == tile_row[None, :],
                                  best[:, None], jnp.int32(2**31 - 1)),
                        axis=0).astype(jnp.int32)
    return tile_gain, tile_node, tile_dest


def _dissat_kernel(agg_ref, r_rows_ref, b_rows_ref, theta_rows_ref,
                   loads_ref, speeds_ref, scalars_ref, dissat_ref, best_ref,
                   *, framework: str, k_real: int):
    dissat, best = reduce_dissat_tile(
        agg_ref[...].astype(jnp.float32), r_rows_ref[0, :],
        b_rows_ref[0, :], theta_rows_ref[0, :], loads_ref[0, :],
        speeds_ref[0, :], scalars_ref[0, 0], scalars_ref[0, 1],
        framework=framework, k_real=k_real)
    dissat_ref[0, :] = dissat
    best_ref[0, :] = best


def pad_dissat_operands(row_assignment, node_weights, theta, loads, speeds,
                        mu, total_weight, n_rows: int, rows_pad: int,
                        k: int, k_pad: int):
    """Shared operand padding for every dissatisfaction wrapper (the
    aggregate kernels here and :mod:`repro.kernels.edge_block`) — the
    conventions are load-bearing and must never desync: padded rows
    point at padded machine ``k_pad - 1`` with zero weight/theta (their
    outputs are sliced off), padded speeds are 1.0 (no div-by-zero),
    ``theta=None`` rides an exact zero operand.  Returns
    ``(r_rows, b, theta, loads, speeds, scalars)`` in kernel layout."""
    r_rows = jnp.full((1, rows_pad), k_pad - 1, jnp.int32).at[0, :n_rows].set(
        jnp.asarray(row_assignment, jnp.int32))
    b = jnp.zeros((1, rows_pad), jnp.float32).at[0, :n_rows].set(
        node_weights.astype(jnp.float32))
    t = jnp.zeros((1, rows_pad), jnp.float32)
    if theta is not None:
        t = t.at[0, :n_rows].set(
            jnp.broadcast_to(jnp.asarray(theta, jnp.float32), (n_rows,)))
    l_pad = jnp.zeros((1, k_pad), jnp.float32).at[0, :k].set(
        loads.astype(jnp.float32))
    w_pad = jnp.ones((1, k_pad), jnp.float32).at[0, :k].set(
        speeds.astype(jnp.float32))
    scalars = jnp.stack([jnp.asarray(mu, jnp.float32),
                         jnp.asarray(total_weight, jnp.float32)])[None, :]
    return r_rows, b, t, l_pad, w_pad, scalars


def dissatisfaction_from_aggregate_pallas(
        aggregate: Array, row_assignment: Array, node_weights: Array,
        loads: Array, speeds: Array, mu, framework: str = "c", *,
        theta: Array | None = None, total_weight: Array | None = None,
        tile_n: int = DEFAULT_TILE_N,
        interpret: bool | None = None) -> tuple[Array, Array]:
    """Fused Eq.-4 reduction over an already-built (rows, K) aggregate.

    Returns ``(dissat (rows,), best_machine (rows,))`` without ever
    materializing the (rows, K) cost matrix in HBM: each grid step reads
    one aggregate tile, assembles its cost block in VREGs, and reduces to
    the dissatisfaction + lowest-index arg-best machine in place.  This is
    the per-turn kernel of the incremental refinement path (the aggregate
    itself is maintained by rank-1 carry updates, DESIGN.md §10); row
    blocks of the distributed runtime drive it the same way (pass the
    shard's ``row_assignment`` / ``node_weights`` slices and the global
    ``total_weight``).

    ``theta`` is the optional (rows,) per-node migration-price threshold
    (DESIGN.md §11): the returned dissatisfaction is net of it (subtracted
    in the same fused reduction — still one aggregate read, O(rows) out).
    ``None`` rides a zero operand through the same subtraction, which is
    exact for the finite Eq.-4 values.
    """
    interpret = resolve_interpret(interpret)
    n_rows, k = aggregate.shape
    assert loads.shape[0] == k, (aggregate.shape, loads.shape)
    if total_weight is None:
        total_weight = jnp.sum(node_weights)
    rows_pad = -(-n_rows // tile_n) * tile_n
    k_pad = -(-k // 128) * 128

    a = jnp.zeros((rows_pad, k_pad), jnp.float32)
    a = a.at[:n_rows, :k].set(aggregate.astype(jnp.float32))
    r_rows, b, t, l_pad, w_pad, scalars = pad_dissat_operands(
        row_assignment, node_weights, theta, loads, speeds, mu,
        total_weight, n_rows, rows_pad, k, k_pad)

    num_i = rows_pad // tile_n
    dissat, best = pl.pallas_call(
        functools.partial(_dissat_kernel, framework=framework, k_real=k),
        grid=(num_i,),
        in_specs=[
            pl.BlockSpec((tile_n, k_pad), lambda i: (i, 0)),   # aggregate
            pl.BlockSpec((1, tile_n), lambda i: (0, i)),       # r (rows)
            pl.BlockSpec((1, tile_n), lambda i: (0, i)),       # b (rows)
            pl.BlockSpec((1, tile_n), lambda i: (0, i)),       # theta (rows)
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),        # loads
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),        # speeds
            pl.BlockSpec((1, 2), lambda i: (0, 0)),            # mu, B
        ],
        out_specs=[
            pl.BlockSpec((1, tile_n), lambda i: (0, i)),
            pl.BlockSpec((1, tile_n), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, rows_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, rows_pad), jnp.int32),
        ],
        interpret=interpret,
    )(a, r_rows, b, t, l_pad, w_pad, scalars)
    return dissat[0, :n_rows], best[0, :n_rows]


# ---------------------------------------------------------------------------
# batch-grid variant: one fused call over a stack of B problems (§12.3)
# ---------------------------------------------------------------------------

def _dissat_kernel_batched(agg_ref, r_rows_ref, b_rows_ref, theta_rows_ref,
                           loads_ref, speeds_ref, scalars_ref, dissat_ref,
                           best_ref, *, framework: str, k_real: int):
    """Per-(b, i) grid step — the *identical* op sequence of
    :func:`_dissat_kernel` on batch element b's row tile i (the leading
    block axes are size-1 slabs), so per-element outputs are bitwise
    those of the unbatched kernel."""
    kpad = loads_ref.shape[-1]
    tn = agg_ref.shape[1]
    aggregate = agg_ref[0].astype(jnp.float32)                 # (TN, K)
    mu = scalars_ref[0, 0, 0]
    total_b = scalars_ref[0, 0, 1]
    b = b_rows_ref[0, 0, :].astype(jnp.float32)[:, None]       # (TN, 1)
    r_rows = r_rows_ref[0, 0, :]                               # (TN,)
    kidx = jax.lax.broadcasted_iota(jnp.int32, (tn, kpad), 1)
    own = (r_rows[:, None] == kidx).astype(jnp.float32)
    loads = loads_ref[0, 0, :][None, :]                        # (1, K)
    inv_w = 1.0 / speeds_ref[0, 0, :][None, :]
    degree = jnp.sum(aggregate, axis=-1, keepdims=True)
    others = loads - b * own
    cut_term = 0.5 * mu * (degree - aggregate)
    if framework == "c":
        cost = (b * inv_w) * others + cut_term
    else:
        cost = (b * b) * inv_w * inv_w \
            + 2.0 * b * inv_w * inv_w * others \
            - 2.0 * b * inv_w * total_b + cut_term
    cost = jnp.where(kidx < k_real, cost, _BIG)
    best_val = jnp.min(cost, axis=1)
    best_idx = jnp.min(jnp.where(cost <= best_val[:, None], kidx, kpad),
                       axis=1).astype(jnp.int32)
    current = jnp.sum(jnp.where(own > 0, cost, 0.0), axis=1)
    dissat_ref[0, 0, :] = current - best_val - theta_rows_ref[0, 0, :]
    best_ref[0, 0, :] = best_idx


def dissatisfaction_from_aggregate_batched_pallas(
        aggregate: Array, row_assignment: Array, node_weights: Array,
        loads: Array, speeds: Array, mu: Array, framework: str = "c", *,
        theta: Array | None = None, total_weight: Array | None = None,
        tile_n: int = DEFAULT_TILE_N,
        interpret: bool | None = None) -> tuple[Array, Array]:
    """Fused Eq.-4 reduction over a (B, rows, K) aggregate stack.

    The batch-grid layout of DESIGN.md §12.3: ``grid=(B, rows/TN)`` with
    row tiles innermost; every operand gains a leading batch axis whose
    BlockSpec picks element b's slab, so the one kernel invocation stays
    a single fused program over the whole fleet.  Batched operands:
    ``aggregate (B, rows, K)``, ``row_assignment``/``node_weights``/
    optional ``theta`` ``(B, rows)``, ``loads``/``speeds`` ``(B, K)``,
    ``mu``/optional ``total_weight`` ``(B,)``.  Returns
    ``(dissat (B, rows), best_machine (B, rows))``, per element bitwise
    equal to :func:`dissatisfaction_from_aggregate_pallas` on that
    element's operands.  Reached automatically by ``jax.vmap`` of the
    :mod:`repro.kernels.ops` wrapper (``custom_vmap`` routes here), which
    is how the batched sweep runtime keeps the refinement hot path fused.
    """
    interpret = resolve_interpret(interpret)
    bsz, n_rows, k = aggregate.shape
    assert loads.shape == (bsz, k), (aggregate.shape, loads.shape)
    if total_weight is None:
        total_weight = jnp.sum(node_weights, axis=-1)
    rows_pad = -(-n_rows // tile_n) * tile_n
    k_pad = -(-k // 128) * 128

    a = jnp.zeros((bsz, rows_pad, k_pad), jnp.float32)
    a = a.at[:, :n_rows, :k].set(aggregate.astype(jnp.float32))
    # padded rows point at a padded machine with zero weight (as in the
    # unbatched wrapper); their outputs are sliced off below
    r_rows = jnp.full((bsz, 1, rows_pad), k_pad - 1, jnp.int32)
    r_rows = r_rows.at[:, 0, :n_rows].set(
        jnp.asarray(row_assignment, jnp.int32))
    b = jnp.zeros((bsz, 1, rows_pad), jnp.float32).at[:, 0, :n_rows].set(
        node_weights.astype(jnp.float32))
    t = jnp.zeros((bsz, 1, rows_pad), jnp.float32)
    if theta is not None:
        t = t.at[:, 0, :n_rows].set(
            jnp.broadcast_to(jnp.asarray(theta, jnp.float32),
                             (bsz, n_rows)))
    l_pad = jnp.zeros((bsz, 1, k_pad), jnp.float32).at[:, 0, :k].set(
        loads.astype(jnp.float32))
    w_pad = jnp.ones((bsz, 1, k_pad), jnp.float32).at[:, 0, :k].set(
        speeds.astype(jnp.float32))
    scalars = jnp.stack(
        [jnp.broadcast_to(jnp.asarray(mu, jnp.float32), (bsz,)),
         jnp.broadcast_to(jnp.asarray(total_weight, jnp.float32), (bsz,))],
        axis=-1)[:, None, :]                                   # (B, 1, 2)

    num_i = rows_pad // tile_n
    dissat, best = pl.pallas_call(
        functools.partial(_dissat_kernel_batched, framework=framework,
                          k_real=k),
        grid=(bsz, num_i),
        in_specs=[
            pl.BlockSpec((1, tile_n, k_pad), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, tile_n), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, 1, tile_n), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, 1, tile_n), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, 1, k_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, k_pad), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, 2), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tile_n), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, 1, tile_n), lambda b, i: (b, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, 1, rows_pad), jnp.float32),
            jax.ShapeDtypeStruct((bsz, 1, rows_pad), jnp.int32),
        ],
        interpret=interpret,
    )(a, r_rows, b, t, l_pad, w_pad, scalars)
    return dissat[:, 0, :n_rows], best[:, 0, :n_rows]
