"""Fused Pallas TPU kernel for the refinement hot spot (DESIGN.md §3.2).

Every refinement turn needs the full (N, K) node-cost matrix, whose dominant
work is the adjacency aggregation  A[i, k] = sum_j c_ij * 1[r_j = k]  — an
(N x N) @ (N x K) matmul.  Computing A with jnp and then assembling costs
reads the (N, K) intermediates from HBM several times; this kernel tiles the
adjacency into VMEM blocks, accumulates A on the MXU, and fuses the entire
cost assembly (load term + cut term for either framework) into the final
grid step, so the adjacency is read exactly once and nothing but the (N, K)
cost matrix is written back.

Grid: (N/TN, N/TJ), j innermost.  Per (i, j) step:
  * build the one-hot of the column block's assignments (TJ, K) in VREGs,
  * acc(TN, K) += C_block(TN, TJ) @ onehot  (MXU),
  * at j == last: assemble the cost block and write it out.

All tile dims are multiples of the 128-lane MXU width; K is padded to 128
lanes by the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_TILE_N = 128
DEFAULT_TILE_J = 128


def _kernel(c_ref, r_cols_ref, r_rows_ref, b_rows_ref, loads_ref, speeds_ref,
            scalars_ref, out_ref, acc_ref, *, framework: str, num_j: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kpad = loads_ref.shape[-1]
    r_cols = r_cols_ref[0, :]                                  # (TJ,) int32
    onehot = (r_cols[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, kpad), 1)
              ).astype(jnp.float32)                            # (TJ, K)
    acc_ref[...] += jax.lax.dot(
        c_ref[...].astype(jnp.float32), onehot,
        preferred_element_type=jnp.float32)

    @pl.when(j == num_j - 1)
    def _finish():
        aggregate = acc_ref[...]                               # (TN, K)
        mu = scalars_ref[0, 0]
        total_b = scalars_ref[0, 1]
        b = b_rows_ref[0, :].astype(jnp.float32)[:, None]      # (TN, 1)
        r_rows = r_rows_ref[0, :]                              # (TN,)
        own = (r_rows[:, None]
               == jax.lax.broadcasted_iota(jnp.int32, (1, kpad), 1)
               ).astype(jnp.float32)
        loads = loads_ref[0, :][None, :]                       # (1, K)
        inv_w = 1.0 / speeds_ref[0, :][None, :]
        degree = jnp.sum(aggregate, axis=-1, keepdims=True)
        others = loads - b * own
        cut_term = 0.5 * mu * (degree - aggregate)
        if framework == "c":
            cost = (b * inv_w) * others + cut_term
        else:
            cost = (b * b) * inv_w * inv_w \
                + 2.0 * b * inv_w * inv_w * others \
                - 2.0 * b * inv_w * total_b + cut_term
        out_ref[...] = cost


def cost_matrix_pallas(adjacency: Array, assignment: Array, node_weights: Array,
                       loads: Array, speeds: Array, mu,
                       framework: str = "c", *,
                       tile_n: int = DEFAULT_TILE_N,
                       tile_j: int = DEFAULT_TILE_J,
                       interpret: bool = True,
                       row_assignment: Array | None = None,
                       total_weight: Array | None = None) -> Array:
    """Padded + tiled pallas_call; returns the (rows, K) cost matrix.

    ``adjacency`` may be rectangular: a ``(rows, N)`` row block of a larger
    graph, as produced by :mod:`repro.distributed.views` — the grid tiles
    rows and columns independently and the contraction runs over the full
    column extent, so each machine of the distributed runtime can drive
    this same kernel on nothing but its shard.  In the row-block case pass
    ``row_assignment`` (length ``rows``, the block nodes' own machines;
    ``assignment`` then covers the N *columns*), ``node_weights`` of length
    ``rows``, and ``total_weight`` = the global sum of b (the Ct framework
    needs B, which a row block cannot compute locally).  Square callers
    keep the original signature: both default to ``assignment`` /
    ``sum(node_weights)``.

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container has no TPU); on real hardware pass interpret=False.
    """
    n_rows, n_cols = adjacency.shape
    k = loads.shape[0]
    if row_assignment is None:
        row_assignment = assignment
    if total_weight is None:
        total_weight = jnp.sum(node_weights)
    rows_pad = -(-n_rows // tile_n) * tile_n
    cols_pad = -(-n_cols // tile_j) * tile_j
    k_pad = -(-k // 128) * 128

    c = jnp.zeros((rows_pad, cols_pad), adjacency.dtype)
    c = c.at[:n_rows, :n_cols].set(adjacency)
    # padded rows/columns point at a padded machine so they never pollute
    # real K (and padded rows carry zero weight)
    r_cols = jnp.full((1, cols_pad), k_pad - 1, jnp.int32).at[0, :n_cols].set(
        jnp.asarray(assignment, jnp.int32))
    r_rows = jnp.full((1, rows_pad), k_pad - 1, jnp.int32).at[0, :n_rows].set(
        jnp.asarray(row_assignment, jnp.int32))
    b = jnp.zeros((1, rows_pad), jnp.float32).at[0, :n_rows].set(
        node_weights.astype(jnp.float32))
    l_pad = jnp.zeros((1, k_pad), jnp.float32).at[0, :k].set(
        loads.astype(jnp.float32))
    w_pad = jnp.ones((1, k_pad), jnp.float32).at[0, :k].set(
        speeds.astype(jnp.float32))
    scalars = jnp.stack([jnp.asarray(mu, jnp.float32),
                         jnp.asarray(total_weight, jnp.float32)])[None, :]

    num_i = rows_pad // tile_n
    num_j = cols_pad // tile_j
    out = pl.pallas_call(
        functools.partial(_kernel, framework=framework, num_j=num_j),
        grid=(num_i, num_j),
        in_specs=[
            pl.BlockSpec((tile_n, tile_j), lambda i, j: (i, j)),   # adjacency
            pl.BlockSpec((1, tile_j), lambda i, j: (0, j)),        # r (cols)
            pl.BlockSpec((1, tile_n), lambda i, j: (0, i)),        # r (rows)
            pl.BlockSpec((1, tile_n), lambda i, j: (0, i)),        # b (rows)
            pl.BlockSpec((1, k_pad), lambda i, j: (0, 0)),         # loads
            pl.BlockSpec((1, k_pad), lambda i, j: (0, 0)),         # speeds
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),             # mu, B
        ],
        out_specs=pl.BlockSpec((tile_n, k_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, k_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile_n, k_pad), jnp.float32)],
        interpret=interpret,
    )(c, r_cols, r_rows, b, l_pad, w_pad, scalars)
    return out[:n_rows, :k]
