"""Pallas TPU kernels for the framework's compute hot spots.

  * dissatisfaction.py  — fused adjacency-aggregation + cost-matrix kernel
    for the partition game's refinement loop (the paper's §4.5 hot spot).
  * edge_block.py       — fused edge-list → dissatisfaction kernel for the
    sparse runtime (DESIGN.md §13.3): O(E) traffic, no dense adjacency.
  * flash_attention.py  — blocked causal GQA attention forward (online
    softmax, causal block-skip) for train/prefill.
  * decode_attention.py — flash-decoding GQA attention for serve_step.
  * ssd_scan.py         — Mamba2 SSD chunked scan with the recurrent state
    resident in VMEM across chunks.

Each kernel ships with a pure-jnp oracle in ref.py and a jitted wrapper in
ops.py; tests sweep shapes/dtypes and assert allclose (interpret=True on
this CPU-only container, compiled on real TPUs).
"""
from . import ops, ref  # noqa: F401
from .ops import (  # noqa: F401
    cost_matrix,
    decode_attention,
    flash_attention,
    make_aggregate_dissat_fn,
    make_core_cost_matrix_fn,
    make_edge_dissat_fn,
    ssd_scan,
)
