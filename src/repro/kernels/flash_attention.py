"""Flash-attention FORWARD Pallas kernel: blocked causal GQA attention.

This is the hardware realization of the §Perf analytic memory floor for
train/prefill attention: Q, K, V stream through VMEM in blocks with an
online-softmax accumulator, so the S x S logits never touch HBM — the
XLA-level `attn_q_chunks` path (models/attention.py) bounds peak memory
but still pays the S² HBM traffic; this kernel removes it (HBM traffic =
one Q/K/V read + one O write, the roofline minimum).

Layouts (one grid cell per (batch, kv-head, q-block); k innermost):
  q   (B, S, Hkv, G, D)  — query heads grouped under their kv head
  k,v (B, S, Hkv, D)
  out (B, S, Hkv, G, D)
Block shapes: q (1, TQ, 1, G, D) flattened to (TQ*G, D) rows for the MXU;
k/v (1, TK, 1, D).  Scratch: acc (TQ*G, D), m/l (TQ*G, 128) f32.

Causality: k-blocks strictly in the future of a q-block are skipped with
``pl.when`` (half the blocks at long S — the FLOP skip the XLA path
cannot express); the diagonal blocks mask per element via iota.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_TILE_Q = 128
DEFAULT_TILE_K = 128
_NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref, *,
            tile_q: int, tile_k: int, num_k: int, groups: int,
            scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal block skip: this k block starts after the q block ends
    q_start = qi * tile_q
    k_start = ki * tile_k

    @pl.when(k_start <= q_start + tile_q - 1)
    def _compute():
        q = q_ref[0, :, 0].astype(jnp.float32) * scale   # (TQ, G, D)
        q2 = q.reshape(tile_q * groups, q.shape[-1])     # (TQ*G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (TK, D)
        v = v_ref[0, :, 0].astype(jnp.float32)           # (TK, D)

        logits = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (TQ*G, TK)
        # causal mask on absolute positions: row r belongs to q position
        # q_start + r // G; column c is k position k_start + c
        rows = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        q_pos = q_start + rows // groups
        k_pos = k_start + cols
        logits = jnp.where(q_pos >= k_pos, logits, _NEG_INF)

        m_prev = m_ref[:, :1]                            # (TQ*G, 1)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)                      # (TQ*G, TK)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True),
            l_ref.shape)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ki == num_k - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        out = (acc_ref[...] / denom).astype(out_ref.dtype)
        out_ref[0, :, 0] = out.reshape(tile_q, groups, out.shape[-1])


def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           tile_q: int = DEFAULT_TILE_Q,
                           tile_k: int = DEFAULT_TILE_K,
                           interpret: bool = True) -> Array:
    """Causal GQA attention.  q (B,S,H,D), k/v (B,S,Hkv,D) -> (B,S,H,D).

    ``interpret=True`` executes on CPU for validation; on TPU pass
    interpret=False for the compiled kernel.
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv

    tile_q = min(tile_q, S)
    tile_k = min(tile_k, S)
    s_pad_q = -(-S // tile_q) * tile_q
    s_pad_k = -(-S // tile_k) * tile_k
    s_pad = max(s_pad_q, s_pad_k)
    d_pad = -(-D // 128) * 128

    qg = q.reshape(B, S, Hkv, G, D)
    qg = jnp.pad(qg, ((0, 0), (0, s_pad - S), (0, 0), (0, 0),
                      (0, d_pad - D)))
    kp = jnp.pad(k, ((0, 0), (0, s_pad - S), (0, 0), (0, d_pad - D)))
    vp = jnp.pad(v, ((0, 0), (0, s_pad - S), (0, 0), (0, d_pad - D)))

    num_q = s_pad // tile_q
    num_k = s_pad // tile_k
    out = pl.pallas_call(
        functools.partial(_kernel, tile_q=tile_q, tile_k=tile_k,
                          num_k=num_k, groups=G, scale=1.0 / (D ** 0.5)),
        grid=(B, Hkv, num_q, num_k),
        in_specs=[
            pl.BlockSpec((1, tile_q, 1, G, d_pad),
                         lambda b, h, qi, ki: (b, qi, h, 0, 0)),
            pl.BlockSpec((1, tile_k, 1, d_pad),
                         lambda b, h, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, tile_k, 1, d_pad),
                         lambda b, h, qi, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, 1, G, d_pad),
                               lambda b, h, qi, ki: (b, qi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, s_pad, Hkv, G, d_pad), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q * G, d_pad), jnp.float32),
            pltpu.VMEM((tile_q * G, 128), jnp.float32),
            pltpu.VMEM((tile_q * G, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kp, vp)
    return out[:, :S, :, :, :D].reshape(B, S, H, D)
