"""Flash-decoding style Pallas kernel: single-token GQA attention over a
long KV cache (the serve_step hot spot for decode_32k / long_500k shapes).

One grid cell handles one (batch, kv-head) pair; the KV cache is streamed
through VMEM in (TS, D) chunks with an online-softmax accumulator, so HBM
traffic is exactly one read of K and V — the roofline minimum for decode
(decode attention is memory-bound: ~2*S*D bytes moved for ~2*S*D*G FLOPs).

Layouts:
  q   (B, Hkv, G, D)  — query heads grouped under their kv head
  k,v (B, S, Hkv, D)
  out (B, Hkv, G, D)
Grid (B, Hkv, S/TS), s innermost; scratch: acc (G, D), m/l (G, 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_TILE_S = 512
_NEG_INF = -1.0e30


def _kernel(len_ref, q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref,
            *, tile_s: int, num_s: int, scale: float):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale                # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                  # (TS, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)                  # (TS, D)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (G, TS)
    offs = s * tile_s + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    valid = offs < len_ref[0, 0]
    logits = jnp.where(valid, logits, _NEG_INF)

    m_prev = m_ref[:, :1]                                      # (G, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                                # (G, TS)
    corr = jnp.exp(m_prev - m_new)                             # (G, 1)
    l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(s == num_s - 1)
    def _finish():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[:, :1], 1e-30)
                         ).astype(out_ref.dtype)


def decode_attention_pallas(q: Array, k: Array, v: Array, length: Array, *,
                            tile_s: int = DEFAULT_TILE_S,
                            interpret: bool = True) -> Array:
    """q (B,H,D), k/v (B,S,Hkv,D), length (B,) -> (B,H,D)."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    g_pad = max(8, -(-G // 8) * 8)
    d_pad = -(-D // 128) * 128
    tile_s = min(tile_s, -(-S // 128) * 128)
    s_pad = -(-S // tile_s) * tile_s

    qg = q.reshape(B, Hkv, G, D)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - G), (0, d_pad - D)))
    kp = jnp.pad(k, ((0, 0), (0, s_pad - S), (0, 0), (0, d_pad - D)))
    vp = jnp.pad(v, ((0, 0), (0, s_pad - S), (0, 0), (0, d_pad - D)))
    lens = jnp.asarray(length, jnp.int32).reshape(B, 1)

    num_s = s_pad // tile_s
    out = pl.pallas_call(
        functools.partial(_kernel, tile_s=tile_s, num_s=num_s,
                          scale=1.0 / (D ** 0.5)),
        grid=(B, Hkv, num_s),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),              # length
            pl.BlockSpec((1, 1, g_pad, d_pad), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, tile_s, 1, d_pad), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, tile_s, 1, d_pad), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g_pad, d_pad),
                               lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g_pad, d_pad), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g_pad, d_pad), jnp.float32),
            pltpu.VMEM((g_pad, 128), jnp.float32),
            pltpu.VMEM((g_pad, 128), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qg, kp, vp)
    return out[:, :, :G, :D].reshape(B, H, D)
