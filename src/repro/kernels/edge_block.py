"""Fused Pallas edge-block dissatisfaction kernel (DESIGN.md §13.3).

The sparse runtime's from-scratch per-turn reduction: edges in, Eq.-4
``(dissat, best_machine)`` out, with neither the (N, K) aggregate nor
the (N, K) cost matrix ever written to HBM.  This is the edge-list twin
of :func:`repro.kernels.dissatisfaction.cost_matrix_pallas` — O(E)
kernel traffic instead of the dense kernel's O(N^2) adjacency read —
reached through the same canonical 9-argument ``dissat_fn`` convention
via :func:`repro.kernels.ops.make_edge_dissat_fn`.

Layout (:func:`build_edge_tile_layout`, built host-side once per
problem): the sender-sorted edge list is re-blocked into per-row-tile
slabs — row tile i (``tile_n`` nodes) owns the contiguous edge range
whose senders fall in ``[i*tile_n, (i+1)*tile_n)``, padded to the fleet
maximum ``EB`` (multiple of ``tile_e``).  Stored per edge:

  * ``local_senders`` (T, EB) — sender minus the tile's row offset, so a
    one-hot against a TN-iota scatters the edge to its row *inside
    VREGs*; padding points at row ``tile_n`` (matches nothing).
  * ``recv_index``    (T, EB) — global receiver id.  The wrapper gathers
    ``assignment[recv_index]`` (one O(E) XLA gather, the only
    assignment-dependent prep) so the kernel itself never gathers.
  * ``edge_w``        (T, EB) — weight, 0.0 on padding (exact +0.0
    contributions, the DESIGN.md §13.1 padding rule).

Grid ``(T, EB/tile_e)``, edge blocks innermost.  Per step the kernel
forms the (TN, TE) sender one-hot and the weighted (TE, K) receiver
one-hot and accumulates their product on the MXU:

    acc(TN, K) += onehot_send @ (w * onehot_recv)

— i.e. the segment-sum aggregate of DESIGN.md §13.2 as a matmul.  At
the last edge block the tile's aggregate is complete in VMEM scratch
and the shared epilogue
(:func:`repro.kernels.dissatisfaction.reduce_dissat_tile` — the same
ops in the same order as the aggregate kernels, preserving the §7
tie-break) reduces it straight to the dissatisfaction rows.

Two kernels share that layout and accumulation
(:func:`_accumulate_edge_block`): :func:`_edge_dissat_kernel` emits the
per-node ``(dissat, best)`` rows, and :func:`_edge_sweep_kernel`
(DESIGN.md §17.4) goes one reduction further — its epilogue
(:func:`~repro.kernels.dissatisfaction.reduce_sweep_tile`, which calls
``reduce_dissat_tile`` first) folds each row tile to per-MACHINE sweep
election partials, so :func:`sweep_candidates_from_edges_pallas` feeds
``refine_sweeps``'s whole candidate pass from ONE edge stream per
sweep, with only O(T·K) partials leaving the kernel.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dissatisfaction import (DEFAULT_TILE_N, pad_dissat_operands,
                              reduce_dissat_tile, reduce_sweep_tile,
                              resolve_interpret)

Array = jax.Array

DEFAULT_TILE_E = 128


class EdgeTileLayout(NamedTuple):
    """Row-tile-aligned edge slabs (see module docstring)."""
    local_senders: Array   # (T, EB) int32; padding = tile_n
    recv_index: Array      # (T, EB) int32; padding = 0 (weight-0 slot)
    edge_w: Array          # (T, EB) float32; padding = 0.0
    num_nodes: int
    tile_n: int
    tile_e: int


def build_edge_tile_layout(sp, tile_n: int = DEFAULT_TILE_N,
                           tile_e: int = DEFAULT_TILE_E) -> EdgeTileLayout:
    """Re-block a :class:`~repro.core.sparse.SparseProblem`'s edge list
    into per-row-tile slabs (host-side numpy, once per problem — the
    layout depends only on the static graph, not on any assignment)."""
    senders = np.asarray(sp.senders)
    receivers = np.asarray(sp.receivers)
    weights = np.asarray(sp.edge_weights, np.float32)
    n = sp.num_nodes
    num_tiles = -(-n // tile_n)
    # sender-sorted => each tile's edges are one contiguous range
    bounds = np.searchsorted(senders,
                             np.arange(num_tiles + 1) * tile_n, side="left")
    counts = np.diff(bounds)
    eb = -(-max(int(counts.max(initial=1)), 1) // tile_e) * tile_e
    ls = np.full((num_tiles, eb), tile_n, np.int32)
    ri = np.zeros((num_tiles, eb), np.int32)
    ew = np.zeros((num_tiles, eb), np.float32)
    for t in range(num_tiles):
        lo, hi = int(bounds[t]), int(bounds[t + 1])
        c = hi - lo
        ls[t, :c] = senders[lo:hi] - t * tile_n
        ri[t, :c] = receivers[lo:hi]
        ew[t, :c] = weights[lo:hi]
    return EdgeTileLayout(local_senders=jnp.asarray(ls),
                          recv_index=jnp.asarray(ri),
                          edge_w=jnp.asarray(ew),
                          num_nodes=n, tile_n=tile_n, tile_e=tile_e)


def _accumulate_edge_block(ls_ref, ra_ref, ew_ref, loads_ref, acc_ref):
    """The shared per-step edge-slab accumulation (module docstring):
    acc(TN, K) += onehot_send @ (w * onehot_recv) on the MXU.  Both
    edge-block kernels (dissatisfaction and sweep election) run exactly
    this, so their carried aggregates are bitwise identical."""
    kpad = loads_ref.shape[-1]
    tn = acc_ref.shape[0]
    te = ls_ref.shape[-1]
    ls = ls_ref[0, :]                                          # (TE,)
    ra = ra_ref[0, :]                                          # (TE,)
    w = ew_ref[0, :].astype(jnp.float32)                       # (TE,)
    send_oh = (jax.lax.broadcasted_iota(jnp.int32, (tn, te), 0)
               == ls[None, :]).astype(jnp.float32)             # (TN, TE)
    recv_oh = (ra[:, None]
               == jax.lax.broadcasted_iota(jnp.int32, (te, kpad), 1)
               ).astype(jnp.float32) * w[:, None]              # (TE, K)
    acc_ref[...] += jax.lax.dot(send_oh, recv_oh,
                                preferred_element_type=jnp.float32)


def _edge_dissat_kernel(ls_ref, ra_ref, ew_ref, r_rows_ref, b_rows_ref,
                        theta_rows_ref, loads_ref, speeds_ref, scalars_ref,
                        dissat_ref, best_ref, acc_ref, *, framework: str,
                        k_real: int, num_e: int):
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate_edge_block(ls_ref, ra_ref, ew_ref, loads_ref, acc_ref)

    @pl.when(e == num_e - 1)
    def _finish():
        dissat, best = reduce_dissat_tile(
            acc_ref[...], r_rows_ref[0, :], b_rows_ref[0, :],
            theta_rows_ref[0, :], loads_ref[0, :], speeds_ref[0, :],
            scalars_ref[0, 0], scalars_ref[0, 1],
            framework=framework, k_real=k_real)
        dissat_ref[0, :] = dissat
        best_ref[0, :] = best


def _edge_in_specs(tile_e: int, tile_n: int, k_pad: int):
    """The shared input BlockSpecs of both edge-block kernels: edge
    slabs stream (tile, edge-block)-wise, row operands per row tile,
    (K,) operands and scalars broadcast to every step."""
    return [
        pl.BlockSpec((1, tile_e), lambda i, e: (i, e)),    # local send
        pl.BlockSpec((1, tile_e), lambda i, e: (i, e)),    # recv assign
        pl.BlockSpec((1, tile_e), lambda i, e: (i, e)),    # edge weight
        pl.BlockSpec((1, tile_n), lambda i, e: (0, i)),    # r (rows)
        pl.BlockSpec((1, tile_n), lambda i, e: (0, i)),    # b (rows)
        pl.BlockSpec((1, tile_n), lambda i, e: (0, i)),    # theta (rows)
        pl.BlockSpec((1, k_pad), lambda i, e: (0, 0)),     # loads
        pl.BlockSpec((1, k_pad), lambda i, e: (0, 0)),     # speeds
        pl.BlockSpec((1, 2), lambda i, e: (0, 0)),         # mu, B
    ]


def dissatisfaction_from_edges_pallas(
        layout: EdgeTileLayout, assignment: Array, node_weights: Array,
        loads: Array, speeds: Array, mu, framework: str = "c", *,
        theta: Array | None = None, total_weight: Array | None = None,
        interpret: bool | None = None) -> tuple[Array, Array]:
    """Fused Eq.-4 reduction straight from edge slabs (module docstring).

    ``assignment``/``node_weights``/``theta`` are full-graph (N,) arrays;
    the receiver-assignment gather happens here (one XLA gather), all
    remaining work inside the kernel.  Returns ``(dissat (N,), best (N,))``
    matching :func:`...dissatisfaction_from_aggregate_pallas` fed the
    segment-sum aggregate — same epilogue ops, so identical tie-breaks.
    """
    interpret = resolve_interpret(interpret)
    n = layout.num_nodes
    tile_n, tile_e = layout.tile_n, layout.tile_e
    num_tiles, eb = layout.local_senders.shape
    rows_pad = num_tiles * tile_n
    k = loads.shape[0]
    k_pad = -(-k // 128) * 128
    if total_weight is None:
        total_weight = jnp.sum(node_weights)

    recv_assign = jnp.take(jnp.asarray(assignment, jnp.int32),
                           layout.recv_index)                  # (T, EB)
    r_rows, b, t, l_pad, w_pad, scalars = pad_dissat_operands(
        assignment, node_weights, theta, loads, speeds, mu, total_weight,
        n, rows_pad, k, k_pad)

    num_e = eb // tile_e
    dissat, best = pl.pallas_call(
        functools.partial(_edge_dissat_kernel, framework=framework,
                          k_real=k, num_e=num_e),
        grid=(num_tiles, num_e),
        in_specs=_edge_in_specs(tile_e, tile_n, k_pad),
        out_specs=[
            pl.BlockSpec((1, tile_n), lambda i, e: (0, i)),
            pl.BlockSpec((1, tile_n), lambda i, e: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, rows_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, rows_pad), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((tile_n, k_pad), jnp.float32)],
        interpret=interpret,
    )(layout.local_senders, recv_assign, layout.edge_w, r_rows, b, t,
      l_pad, w_pad, scalars)
    return dissat[0, :n], best[0, :n]


def _edge_sweep_kernel(ls_ref, ra_ref, ew_ref, r_rows_ref, b_rows_ref,
                       theta_rows_ref, loads_ref, speeds_ref, scalars_ref,
                       gain_ref, node_ref, dest_ref, acc_ref, *,
                       framework: str, k_real: int, num_e: int, n_real: int):
    e = pl.program_id(1)
    row_base = pl.program_id(0) * acc_ref.shape[0]

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate_edge_block(ls_ref, ra_ref, ew_ref, loads_ref, acc_ref)

    @pl.when(e == num_e - 1)
    def _finish():
        gain, node, dest = reduce_sweep_tile(
            acc_ref[...], r_rows_ref[0, :], b_rows_ref[0, :],
            theta_rows_ref[0, :], loads_ref[0, :], speeds_ref[0, :],
            scalars_ref[0, 0], scalars_ref[0, 1], row_base,
            framework=framework, k_real=k_real, n_real=n_real)
        gain_ref[0, :] = gain
        node_ref[0, :] = node
        dest_ref[0, :] = dest


def sweep_candidates_from_edges_pallas(
        layout: EdgeTileLayout, assignment: Array, node_weights: Array,
        loads: Array, speeds: Array, mu, framework: str = "c", *,
        theta: Array | None = None, total_weight: Array | None = None,
        interpret: bool | None = None) -> tuple[Array, Array, Array]:
    """Fused per-machine sweep election straight from edge slabs
    (DESIGN.md §17.4): one pass over the edges per SWEEP, not per node.

    Same grid, operands and per-step accumulation as
    :func:`dissatisfaction_from_edges_pallas`; the last edge block runs
    :func:`~repro.kernels.dissatisfaction.reduce_sweep_tile` — which
    extends the shared ``reduce_dissat_tile`` epilogue — writing each
    row tile's (K,) election partials (best gain / winning node / its
    destination).  The (T, K) partials combine host-side by a
    first-maximum argmax over the tile axis: the lowest winning tile
    contains the globally lowest winning node index, so the combined
    election matches the jnp path's ``jnp.argmax`` tie-break
    (DESIGN.md §7) exactly.

    Returns ``(gains (K,), picks (K,), dests (K,))`` — the
    :class:`~repro.core.refine.SweepCandidateFn` payload.  Machines
    owning no node get gain ``-3e38`` (never above any threshold).
    """
    interpret = resolve_interpret(interpret)
    n = layout.num_nodes
    tile_n, tile_e = layout.tile_n, layout.tile_e
    num_tiles, eb = layout.local_senders.shape
    rows_pad = num_tiles * tile_n
    k = loads.shape[0]
    k_pad = -(-k // 128) * 128
    if total_weight is None:
        total_weight = jnp.sum(node_weights)

    recv_assign = jnp.take(jnp.asarray(assignment, jnp.int32),
                           layout.recv_index)                  # (T, EB)
    r_rows, b, t, l_pad, w_pad, scalars = pad_dissat_operands(
        assignment, node_weights, theta, loads, speeds, mu, total_weight,
        n, rows_pad, k, k_pad)

    num_e = eb // tile_e
    gains_t, nodes_t, dests_t = pl.pallas_call(
        functools.partial(_edge_sweep_kernel, framework=framework,
                          k_real=k, num_e=num_e, n_real=n),
        grid=(num_tiles, num_e),
        in_specs=_edge_in_specs(tile_e, tile_n, k_pad),
        out_specs=[
            pl.BlockSpec((1, k_pad), lambda i, e: (i, 0)),
            pl.BlockSpec((1, k_pad), lambda i, e: (i, 0)),
            pl.BlockSpec((1, k_pad), lambda i, e: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((num_tiles, k_pad), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, k_pad), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((tile_n, k_pad), jnp.float32)],
        interpret=interpret,
    )(layout.local_senders, recv_assign, layout.edge_w, r_rows, b, t,
      l_pad, w_pad, scalars)
    # host combine: first-maximum over tiles = globally lowest node index
    g = gains_t[:, :k]                                         # (T, K)
    win_tile = jnp.argmax(g, axis=0)
    karange = jnp.arange(k)
    return (jnp.max(g, axis=0), nodes_t[win_tile, karange],
            dests_t[win_tile, karange])
