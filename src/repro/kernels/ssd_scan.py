"""Pallas kernel for the Mamba2 SSD (state-space duality) chunked scan.

One grid cell per (batch, head, chunk); chunks iterate innermost and carry
the (P, N) recurrent state in VMEM scratch, so the state never round-trips
HBM between chunks — the memory floor for SSM train/prefill (the pure-JAX
path in models/ssm.py stages the inter-chunk states through a lax.scan
carry in HBM).

Per chunk (all f32, following arXiv:2405.21060 §6):
  da       = dt * a                      (Q,)  — precomputed outside
  cum      = cumsum(da)                  (Q,)
  L[i, j]  = exp(cum_i - cum_j) · 1[i >= j]
  scores   = (C B^T) ⊙ L ⊙ dt_j          (Q, Q)
  y        = scores @ x                      — intra-chunk (quadratic) part
           + (C ⊙ exp(cum)) @ state^T        — inter-chunk (recurrent) part
  state   <- state · exp(cum_Q) + x^T @ (B ⊙ exp(cum_Q - cum) ⊙ dt)

Layouts: x/y (B, L, H, P); dt/da pre-transposed to (B, H, L) so the block's
last dim is the 128-long chunk; bm/cm (B, L, N) shared across heads; the
final state (B, H, P, N) is a second output written at the last chunk
(prefill hands it to the decode cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_CHUNK = 128


def _kernel(x_ref, da_ref, dt_ref, bm_ref, cm_ref, y_ref, state_out_ref,
            state_ref, *, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)            # (Q, P)
    da = da_ref[0, 0].astype(jnp.float32)             # (Q,)
    dt = dt_ref[0, 0].astype(jnp.float32)             # (Q,)
    bm = bm_ref[0].astype(jnp.float32)                # (Q, N)
    cm = cm_ref[0].astype(jnp.float32)                # (Q, N)

    cum = jnp.cumsum(da)                              # (Q,)
    decay = jnp.exp(cum[:, None] - cum[None, :])      # (Q, Q)
    rows = jax.lax.broadcasted_iota(jnp.int32, decay.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, decay.shape, 1)
    lmat = jnp.where(rows >= cols, decay, 0.0)

    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    scores = cb * lmat * dt[None, :]
    y_diag = jax.lax.dot(scores, x, preferred_element_type=jnp.float32)

    state = state_ref[...]                            # (P, N)
    y_off = jax.lax.dot_general(
        cm * jnp.exp(cum)[:, None], state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (Q, P)

    total = jnp.exp(cum[-1])
    wts = jnp.exp(cum[-1] - cum) * dt                 # (Q,)
    inc = jax.lax.dot_general(
        x, bm * wts[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (P, N)
    state_ref[...] = state * total + inc

    y_ref[0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...].astype(state_out_ref.dtype)


def ssd_scan_pallas(x: Array, dt: Array, a: Array, bm: Array, cm: Array,
                    chunk: int = DEFAULT_CHUNK, *,
                    interpret: bool = True):
    """x (B,L,H,P), dt (B,L,H), a (H,), bm/cm (B,L,N) ->
    (y (B,L,H,P) f32, final_state (B,H,P,N) f32).

    Arbitrary L: zero-padded to a chunk multiple (dt=0 on the pad leaves
    the state untouched, padded outputs are sliced off).
    """
    B, L, H, P = x.shape
    N = bm.shape[-1]
    Q = min(chunk, L)
    L_pad = -(-L // Q) * Q
    if L_pad != L:
        x = jnp.pad(x, ((0, 0), (0, L_pad - L), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, L_pad - L), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, L_pad - L), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, L_pad - L), (0, 0)))
    nc = L_pad // Q

    da_t = jnp.moveaxis(dt * a[None, None, :], 1, 2)   # (B, H, L)
    dt_t = jnp.moveaxis(dt, 1, 2)                      # (B, H, L)

    y, final = pl.pallas_call(
        functools.partial(_kernel, num_chunks=nc),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),   # x
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),         # da
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),         # dt
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),         # bm
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),         # cm
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L_pad, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, da_t, dt_t, bm, cm)
    return y[:, :L], final
